//! SLA model and compliance tracking (Eq. 7):
//!
//! ```text
//! SLA(W_i, π(i)) ≥ τ  ∀i
//! ```
//!
//! Each job's SLA is a completion deadline derived from its calibrated
//! solo JCT plus a slack fraction; τ is the required fraction of jobs
//! meeting their deadline (the paper reports τ = 1.0 — *no* violations).

use crate::workload::JobId;
use std::collections::BTreeMap;

/// SLA contract parameters.
#[derive(Debug, Clone, Copy)]
pub struct SlaSpec {
    /// Allowed JCT inflation over the solo baseline (0.10 = +10 %).
    pub slack: f64,
    /// Required compliance fraction τ.
    pub tau: f64,
}

impl Default for SlaSpec {
    fn default() -> Self {
        SlaSpec {
            slack: 0.10,
            tau: 1.0,
        }
    }
}

/// Per-job SLA outcome.
#[derive(Debug, Clone, Copy)]
pub struct JobSla {
    pub solo: f64,
    pub deadline_jct: f64,
    pub jct: Option<f64>,
    pub met: Option<bool>,
}

/// Tracks SLA outcomes over a campaign.
#[derive(Debug, Clone)]
pub struct SlaTracker {
    pub spec: SlaSpec,
    jobs: BTreeMap<JobId, JobSla>,
}

impl SlaTracker {
    pub fn new(spec: SlaSpec) -> SlaTracker {
        SlaTracker {
            spec,
            jobs: BTreeMap::new(),
        }
    }

    /// Register a job at submission with its calibrated solo JCT.
    pub fn register(&mut self, job: JobId, solo: f64) {
        self.jobs.insert(
            job,
            JobSla {
                solo,
                deadline_jct: solo * (1.0 + self.spec.slack),
                jct: None,
                met: None,
            },
        );
    }

    /// Record completion.
    pub fn complete(&mut self, job: JobId, jct: f64) {
        let entry = self.jobs.get_mut(&job).expect("complete unregistered job");
        entry.jct = Some(jct);
        entry.met = Some(jct <= entry.deadline_jct + 1e-9);
    }

    /// Remaining slowdown headroom for a running job that has already
    /// run for `elapsed` and has `remaining_solo` of solo work left —
    /// consumed by the consolidation planner's SLA-safety filter.
    pub fn slack_left(&self, job: JobId, elapsed: f64, remaining_solo: f64) -> f64 {
        match self.jobs.get(&job) {
            Some(s) if remaining_solo > 1e-9 => {
                ((s.deadline_jct - elapsed - remaining_solo) / remaining_solo).max(0.0)
            }
            _ => 0.0,
        }
    }

    pub fn n_completed(&self) -> usize {
        self.jobs.values().filter(|j| j.jct.is_some()).count()
    }

    pub fn n_violations(&self) -> usize {
        self.jobs.values().filter(|j| j.met == Some(false)).count()
    }

    /// Fraction of completed jobs that met their deadline.
    pub fn compliance(&self) -> f64 {
        let done = self.n_completed();
        if done == 0 {
            return 1.0;
        }
        (done - self.n_violations()) as f64 / done as f64
    }

    /// Eq. 7 satisfied?
    pub fn satisfied(&self) -> bool {
        self.compliance() >= self.spec.tau - 1e-12
    }

    /// Mean JCT inflation over solo across completed jobs.
    pub fn mean_slowdown(&self) -> f64 {
        let slow: Vec<f64> = self
            .jobs
            .values()
            .filter_map(|j| j.jct.map(|jct| (jct / j.solo - 1.0).max(-1.0)))
            .collect();
        if slow.is_empty() {
            0.0
        } else {
            slow.iter().sum::<f64>() / slow.len() as f64
        }
    }

    pub fn jobs(&self) -> &BTreeMap<JobId, JobSla> {
        &self.jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compliance_counts_correctly() {
        let mut t = SlaTracker::new(SlaSpec::default());
        t.register(JobId(1), 100.0);
        t.register(JobId(2), 100.0);
        t.register(JobId(3), 100.0);
        t.complete(JobId(1), 105.0); // within +10 %
        t.complete(JobId(2), 109.9); // within
        t.complete(JobId(3), 111.0); // violation
        assert_eq!(t.n_completed(), 3);
        assert_eq!(t.n_violations(), 1);
        assert!((t.compliance() - 2.0 / 3.0).abs() < 1e-12);
        assert!(!t.satisfied()); // τ = 1.0
    }

    #[test]
    fn tau_below_one_tolerates_misses() {
        let mut t = SlaTracker::new(SlaSpec {
            slack: 0.10,
            tau: 0.6,
        });
        t.register(JobId(1), 100.0);
        t.register(JobId(2), 100.0);
        t.complete(JobId(1), 200.0);
        t.complete(JobId(2), 100.0);
        assert!(!t.satisfied()); // 0.5 < 0.6
        t.register(JobId(3), 50.0);
        t.complete(JobId(3), 50.0);
        assert!(t.satisfied()); // 2/3 ≥ 0.6
    }

    #[test]
    fn slack_left_shrinks_as_time_burns() {
        let mut t = SlaTracker::new(SlaSpec::default());
        t.register(JobId(1), 1000.0); // deadline 1100
        // Early: elapsed 100, remaining 900 → (1100-100-900)/900 ≈ 0.111
        let early = t.slack_left(JobId(1), 100.0, 900.0);
        // Late & delayed: elapsed 600, remaining 520 → headroom ~ -20/520 → 0
        let late = t.slack_left(JobId(1), 600.0, 520.0);
        assert!(early > 0.10 && early < 0.12, "{early}");
        assert_eq!(late, 0.0);
        // Unregistered job: zero headroom (be conservative).
        assert_eq!(t.slack_left(JobId(9), 0.0, 10.0), 0.0);
    }

    #[test]
    fn mean_slowdown() {
        let mut t = SlaTracker::new(SlaSpec::default());
        t.register(JobId(1), 100.0);
        t.register(JobId(2), 100.0);
        t.complete(JobId(1), 110.0);
        t.complete(JobId(2), 90.0);
        // (+0.10 + −0.10)/2 = 0.
        assert!(t.mean_slowdown().abs() < 1e-12);
    }

    #[test]
    fn empty_tracker_is_compliant() {
        let t = SlaTracker::new(SlaSpec::default());
        assert_eq!(t.compliance(), 1.0);
        assert!(t.satisfied());
        assert_eq!(t.mean_slowdown(), 0.0);
    }
}
