//! Prediction-engine interface (Eq. 4): `Ê(W_i, h) = f_θ(W_i, R_h)`.
//!
//! A predictor maps placement feature vectors to (marginal power,
//! slowdown risk). Implementations: the XLA-compiled MLP (the paper's
//! learned `f_θ`), a CART decision tree (the paper's "decision tree
//! ranks candidate hosts"), a linear model, the analytic oracle, and a
//! native-Rust MLP (ablation baseline for the XLA path).

use crate::profile::FEAT_DIM;
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;
use std::path::Path;

/// One placement's predicted impact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// Predicted marginal power draw of the placement (W).
    pub power_w: f64,
    /// Predicted relative JCT inflation (0 = no slowdown, 0.5 = +50 %).
    pub slowdown: f64,
}

/// Prediction engine interface. Batch-oriented: the energy-aware
/// scheduler scores all candidate hosts in one call.
///
/// The hot path is [`EnergyPredictor::predict_into`]: the scheduler
/// and the consolidation scan both hold a reusable output buffer, so
/// steady-state scoring performs no per-call allocation.
/// Implementations should override it (the default delegates to
/// `predict`, which allocates a fresh vector per call).
pub trait EnergyPredictor {
    fn name(&self) -> &'static str;

    /// Score a batch of feature vectors into a fresh vector.
    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction>;

    /// Score a batch of feature vectors into a caller-provided buffer.
    /// `out` is cleared first and holds exactly one [`Prediction`] per
    /// input row on return.
    fn predict_into(&mut self, feats: &[[f32; FEAT_DIM]], out: &mut Vec<Prediction>) {
        out.clear();
        out.extend(self.predict(feats));
    }

    /// Duplicate this engine for a parallel shard worker. The clone
    /// must score identically to the original (same rows → bitwise
    /// same predictions) — the parallel/serial equivalence property
    /// tests depend on it. Returns `None` when the engine cannot be
    /// duplicated (e.g. it wraps a device-backed runtime); the
    /// parallel paths then fall back to the serial sweep rather than
    /// sharing one arena across threads.
    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        None
    }

    /// Weight epoch: identifies the parameter set this engine scores
    /// with. The persistent worker pool caches `try_clone`d copies
    /// per worker and re-clones **only** when the cached clone's
    /// epoch is stale, so implementations must return a new value
    /// (drawn from [`next_weight_epoch`]) whenever their weights
    /// change (`set_weights`, retraining) — and clones must report
    /// the epoch of the weights they carry. Instances whose outputs
    /// can differ from other instances of the same type must use
    /// instance-unique epochs (assign one at construction); the
    /// default `0` is reserved for stateless engines where every
    /// instance scores identically (the analytic oracle).
    fn weight_epoch(&self) -> u64 {
        0
    }
}

/// Draw a fresh, process-unique weight epoch (see
/// [`EnergyPredictor::weight_epoch`]). Monotonic and never 0, so
/// epochs from this counter can neither collide across predictor
/// instances nor be mistaken for the stateless default.
pub fn next_weight_epoch() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static COUNTER: AtomicU64 = AtomicU64::new(1);
    COUNTER.fetch_add(1, Ordering::Relaxed)
}

/// Output normalization shared by training and inference:
/// `y0 = power_w / 100`, `y1 = slowdown` (already ~[0, 2]).
pub const POWER_SCALE: f64 = 100.0;

/// MLP architecture constants — must match `python/compile/model.py`.
pub const HIDDEN1: usize = 64;
pub const HIDDEN2: usize = 32;
pub const OUT_DIM: usize = 2;

/// MLP parameters, shared between the native and XLA execution paths
/// and serialized as `artifacts/weights.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpWeights {
    pub w1: Vec<f32>, // [FEAT_DIM, HIDDEN1] row-major
    pub b1: Vec<f32>, // [HIDDEN1]
    pub w2: Vec<f32>, // [HIDDEN1, HIDDEN2]
    pub b2: Vec<f32>, // [HIDDEN2]
    pub w3: Vec<f32>, // [HIDDEN2, OUT_DIM]
    pub b3: Vec<f32>, // [OUT_DIM]
}

impl MlpWeights {
    /// He-initialized random weights (pre-training starting point —
    /// the same init `model.py` uses for its parity tests).
    pub fn init(seed: u64) -> MlpWeights {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut he = |fan_in: usize, n: usize| -> Vec<f32> {
            let std = (2.0 / fan_in as f64).sqrt();
            (0..n).map(|_| (rng.normal(0.0, std)) as f32).collect()
        };
        MlpWeights {
            w1: he(FEAT_DIM, FEAT_DIM * HIDDEN1),
            b1: vec![0.0; HIDDEN1],
            w2: he(HIDDEN1, HIDDEN1 * HIDDEN2),
            b2: vec![0.0; HIDDEN2],
            w3: he(HIDDEN2, HIDDEN2 * OUT_DIM),
            b3: vec![0.0; OUT_DIM],
        }
    }

    pub fn shapes_ok(&self) -> bool {
        self.w1.len() == FEAT_DIM * HIDDEN1
            && self.b1.len() == HIDDEN1
            && self.w2.len() == HIDDEN1 * HIDDEN2
            && self.b2.len() == HIDDEN2
            && self.w3.len() == HIDDEN2 * OUT_DIM
            && self.b3.len() == OUT_DIM
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("w1", Json::from_f32_slice(&self.w1))
            .set("b1", Json::from_f32_slice(&self.b1))
            .set("w2", Json::from_f32_slice(&self.w2))
            .set("b2", Json::from_f32_slice(&self.b2))
            .set("w3", Json::from_f32_slice(&self.w3))
            .set("b3", Json::from_f32_slice(&self.b3));
        o
    }

    pub fn from_json(j: &Json) -> Option<MlpWeights> {
        let w = MlpWeights {
            w1: j.get("w1")?.as_f32_vec()?,
            b1: j.get("b1")?.as_f32_vec()?,
            w2: j.get("w2")?.as_f32_vec()?,
            b2: j.get("b2")?.as_f32_vec()?,
            w3: j.get("w3")?.as_f32_vec()?,
            b3: j.get("b3")?.as_f32_vec()?,
        };
        if w.shapes_ok() {
            Some(w)
        } else {
            None
        }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &Path) -> Option<MlpWeights> {
        let text = std::fs::read_to_string(path).ok()?;
        MlpWeights::from_json(&Json::parse(&text).ok()?)
    }

    /// Parameter tensors in the order the XLA executables take them.
    pub fn as_ordered(&self) -> [(&[f32], [i64; 2]); 6] {
        [
            (&self.w1, [FEAT_DIM as i64, HIDDEN1 as i64]),
            (&self.b1, [1, HIDDEN1 as i64]),
            (&self.w2, [HIDDEN1 as i64, HIDDEN2 as i64]),
            (&self.b2, [1, HIDDEN2 as i64]),
            (&self.w3, [HIDDEN2 as i64, OUT_DIM as i64]),
            (&self.b3, [1, OUT_DIM as i64]),
        ]
    }
}

/// Convert a raw model output row to a [`Prediction`].
pub fn decode_output(y0: f32, y1: f32) -> Prediction {
    Prediction {
        power_w: (y0 as f64 * POWER_SCALE).max(0.0),
        slowdown: (y1 as f64).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_shapes_and_determinism() {
        let a = MlpWeights::init(5);
        let b = MlpWeights::init(5);
        assert!(a.shapes_ok());
        assert_eq!(a, b);
        let c = MlpWeights::init(6);
        assert_ne!(a, c);
    }

    #[test]
    fn json_roundtrip() {
        let w = MlpWeights::init(1);
        let j = w.to_json().to_string();
        let back = MlpWeights::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(w, back);
    }

    #[test]
    fn from_json_rejects_bad_shapes() {
        let mut w = MlpWeights::init(1);
        w.b3.pop();
        let j = w.to_json();
        assert!(MlpWeights::from_json(&j).is_none());
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("ecosched-weights-test");
        let path = dir.join("weights.json");
        let w = MlpWeights::init(2);
        w.save(&path).unwrap();
        assert_eq!(MlpWeights::load(&path).unwrap(), w);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn decode_clamps_negatives() {
        let p = decode_output(-0.5, -0.2);
        assert_eq!(p.power_w, 0.0);
        assert_eq!(p.slowdown, 0.0);
        let p = decode_output(0.35, 0.1);
        assert!((p.power_w - 35.0).abs() < 1e-6);
    }

    #[test]
    fn ordered_params_shapes() {
        let w = MlpWeights::init(3);
        let ord = w.as_ordered();
        assert_eq!(ord[0].1, [16, 64]);
        assert_eq!(ord[5].1, [1, 2]);
        for (data, shape) in ord {
            assert_eq!(data.len() as i64, shape[0] * shape[1]);
        }
    }
}
