//! Native-Rust MLP inference: the same `f_θ` as the XLA path, executed
//! with hand-written matmuls. Exists (a) as the ablation baseline that
//! quantifies what the XLA/PJRT path buys, (b) as a fallback when
//! artifacts are absent (unit tests, docs examples), and (c) as the
//! parity oracle for the Pallas kernel (pytest checks kernel == jnp;
//! the integration test checks XLA == native within f32 tolerance).

use crate::predict::engine::{
    decode_output, EnergyPredictor, MlpWeights, Prediction, HIDDEN1, HIDDEN2, OUT_DIM,
};
use crate::profile::FEAT_DIM;

/// Row-major GEMV: y[j] = Σ_i x[i]·w[i·cols + j] + b[j], then ReLU if
/// `relu`. Simple loops — rustc autovectorizes these fine for our
/// sizes; see benches/bench_predict.rs for the measured comparison.
fn dense(x: &[f32], w: &[f32], b: &[f32], cols: usize, relu: bool, out: &mut [f32]) {
    debug_assert_eq!(w.len(), x.len() * cols);
    debug_assert_eq!(b.len(), cols);
    debug_assert_eq!(out.len(), cols);
    out.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * cols..(i + 1) * cols];
        for (o, &wj) in out.iter_mut().zip(row) {
            *o += xi * wj;
        }
    }
    if relu {
        for o in out.iter_mut() {
            if *o < 0.0 {
                *o = 0.0;
            }
        }
    }
}

/// Native MLP predictor.
#[derive(Debug, Clone)]
pub struct NativeMlp {
    pub weights: MlpWeights,
    // Scratch buffers reused across calls (no allocation on hot path).
    h1: Vec<f32>,
    h2: Vec<f32>,
    y: Vec<f32>,
}

impl NativeMlp {
    pub fn new(weights: MlpWeights) -> NativeMlp {
        assert!(weights.shapes_ok());
        NativeMlp {
            weights,
            h1: vec![0.0; HIDDEN1],
            h2: vec![0.0; HIDDEN2],
            y: vec![0.0; OUT_DIM],
        }
    }

    /// Forward one feature vector; returns the raw (y0, y1) pair.
    pub fn forward(&mut self, f: &[f32; FEAT_DIM]) -> (f32, f32) {
        dense(f, &self.weights.w1, &self.weights.b1, HIDDEN1, true, &mut self.h1);
        dense(&self.h1, &self.weights.w2, &self.weights.b2, HIDDEN2, true, &mut self.h2);
        dense(&self.h2, &self.weights.w3, &self.weights.b3, OUT_DIM, false, &mut self.y);
        // Output activation: softplus keeps both outputs positive and
        // smooth (must match model.py).
        (softplus(self.y[0]), softplus(self.y[1]))
    }
}

#[inline]
pub fn softplus(x: f32) -> f32 {
    // Numerically stable: log1p(exp(-|x|)) + max(x, 0).
    let ax = (-x.abs()).exp();
    ax.ln_1p() + x.max(0.0)
}

impl EnergyPredictor for NativeMlp {
    fn name(&self) -> &'static str {
        "native-mlp"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        feats
            .iter()
            .map(|f| {
                let (y0, y1) = self.forward(f);
                decode_output(y0, y1)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forward_is_deterministic_and_finite() {
        let mut m = NativeMlp::new(MlpWeights::init(7));
        let f = [0.3f32; FEAT_DIM];
        let a = m.forward(&f);
        let b = m.forward(&f);
        assert_eq!(a, b);
        assert!(a.0.is_finite() && a.1.is_finite());
        assert!(a.0 >= 0.0 && a.1 >= 0.0, "softplus outputs nonneg");
    }

    #[test]
    fn dense_matches_manual_computation() {
        // 2×3 layer: x=[1,2], w=[[1,2,3],[4,5,6]], b=[0.5,0.5,0.5].
        let x = [1.0f32, 2.0];
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5f32; 3];
        let mut out = [0.0f32; 3];
        dense(&x, &w, &b, 3, false, &mut out);
        assert_eq!(out, [9.5, 12.5, 15.5]);
    }

    #[test]
    fn relu_clamps() {
        let x = [1.0f32];
        let w = [-5.0f32, 5.0];
        let b = [0.0f32; 2];
        let mut out = [0.0f32; 2];
        dense(&x, &w, &b, 2, true, &mut out);
        assert_eq!(out, [0.0, 5.0]);
    }

    #[test]
    fn softplus_properties() {
        assert!((softplus(0.0) - 0.6931472).abs() < 1e-6);
        assert!(softplus(-30.0) < 1e-9);
        assert!((softplus(30.0) - 30.0).abs() < 1e-6);
        // Monotone.
        assert!(softplus(1.0) > softplus(0.5));
    }

    #[test]
    fn batch_matches_single() {
        let mut m = NativeMlp::new(MlpWeights::init(9));
        let f1 = [0.1f32; FEAT_DIM];
        let mut f2 = [0.0f32; FEAT_DIM];
        f2[0] = 0.9;
        let batch = m.predict(&[f1, f2]);
        let (y0, _) = m.forward(&f1);
        assert!((batch[0].power_w - y0 as f64 * 100.0).abs() < 1e-4);
        assert_ne!(batch[0], batch[1]);
    }

    #[test]
    fn different_inputs_differ() {
        let mut m = NativeMlp::new(MlpWeights::init(3));
        let a = m.forward(&[0.0f32; FEAT_DIM]);
        let b = m.forward(&[1.0f32; FEAT_DIM]);
        assert_ne!(a, b);
    }
}
