//! Native-Rust MLP inference: the same `f_θ` as the XLA path, executed
//! with hand-written matmuls. Exists (a) as the ablation baseline that
//! quantifies what the XLA/PJRT path buys, (b) as a fallback when
//! artifacts are absent (unit tests, docs examples), and (c) as the
//! parity oracle for the Pallas kernel (pytest checks kernel == jnp;
//! the integration test checks XLA == native within f32 tolerance).
//!
//! # Batched execution
//!
//! [`NativeMlp::forward_batch`] runs the whole
//! `(B×16)·(16×64)·(64×32)·(32×2)` pipeline as blocked matmuls: rows
//! are processed in blocks of [`BLOCK`] so the activation scratch
//! stays L2-resident regardless of batch size, and weights/biases
//! stay hot in L1 across the rows of a block. Everything —
//! activations and results — lives in a reusable arena inside the
//! struct, so steady-state scoring performs **zero** allocation.
//!
//! Each row runs the *same* broadcast-form GEMV as the single-row
//! path (`out[j] = b[j] + Σ_i x[i]·w[i][j]`, `i` ascending), so
//! `forward_batch` is bit-identical to row-by-row
//! [`NativeMlp::forward`] — asserted across batch sizes and random
//! weights in `rust/tests/parity.rs`. A transposed-weight dot-product
//! formulation was considered and rejected: without reassociation
//! (`-ffast-math` is never on for this crate) LLVM cannot vectorize a
//! float reduction, which serializes the inner dot on the add-latency
//! chain — an order of magnitude slower than the broadcast form,
//! whose per-`j` lanes are independent and autovectorize.
//!
//! # Branch-free kernels
//!
//! `dense` used to skip `xi == 0.0` input rows. That saved work only
//! when feature rows contained exact zeros (common for idle-host
//! features, rare otherwise) and made per-call FLOPs — and therefore
//! benchmark numbers — data-dependent: the same batch size could
//! differ several-fold in latency depending on host load. The kernel
//! is now branch-free: every call does the same
//! `B·(16·64 + 64·32 + 32·2)` multiply-adds, and
//! `BENCH_predict.json` (written by `benches/bench_predict.rs`)
//! tracks the flat per-row cost across batch sizes {1, 8, 64, 128,
//! 1024} so the tradeoff stays measured rather than assumed.

use crate::predict::engine::{
    decode_output, next_weight_epoch, EnergyPredictor, MlpWeights, Prediction, HIDDEN1, HIDDEN2,
    OUT_DIM,
};
use crate::profile::FEAT_DIM;

/// Row-block size for batched execution: bounds the activation arena
/// at `BLOCK·(64+32+2)` floats (~50 KiB, L2-resident) and matches the
/// XLA artifact's AOT batch so native-vs-XLA comparisons chunk alike.
pub const BLOCK: usize = 128;

/// Row-major GEMV: y[j] = Σ_i x[i]·w[i·cols + j] + b[j], then ReLU if
/// `relu`. Branch-free (see module docs): every input row is
/// accumulated, so FLOPs are batch-shape-independent. Simple loops —
/// rustc autovectorizes the per-`j` lanes; see
/// benches/bench_predict.rs for the measured comparison.
fn dense(x: &[f32], w: &[f32], b: &[f32], cols: usize, relu: bool, out: &mut [f32]) {
    debug_assert_eq!(w.len(), x.len() * cols);
    debug_assert_eq!(b.len(), cols);
    debug_assert_eq!(out.len(), cols);
    out.copy_from_slice(b);
    for (i, &xi) in x.iter().enumerate() {
        let row = &w[i * cols..(i + 1) * cols];
        for (o, &wj) in out.iter_mut().zip(row) {
            *o += xi * wj;
        }
    }
    if relu {
        for o in out.iter_mut() {
            if *o < 0.0 {
                *o = 0.0;
            }
        }
    }
}

/// Batched layer: `rows` independent [`dense`] GEMVs over one flat
/// `[rows·in_dim]` input and `[rows·cols]` output. Reusing the exact
/// single-row kernel per row is what makes batched == single
/// bit-for-bit *by construction*; the batch win comes from arena
/// reuse (zero allocation), one dispatch, and weights staying hot
/// across rows.
fn dense_batch(
    x: &[f32],
    in_dim: usize,
    w: &[f32],
    b: &[f32],
    cols: usize,
    relu: bool,
    y: &mut [f32],
) {
    debug_assert_eq!(x.len() * cols, y.len() * in_dim);
    for (xr, yr) in x.chunks_exact(in_dim).zip(y.chunks_exact_mut(cols)) {
        dense(xr, w, b, cols, relu, yr);
    }
}

/// Native MLP predictor with a reusable scoring arena.
#[derive(Debug, Clone)]
pub struct NativeMlp {
    weights: MlpWeights,
    /// Identifies the current parameter set (instance-unique, bumped
    /// by [`NativeMlp::set_weights`]); `Clone` keeps it, because a
    /// clone carries the same weights and scores bit-identically.
    epoch: u64,
    // Single-row scratch (forward).
    h1: Vec<f32>,
    h2: Vec<f32>,
    y: Vec<f32>,
    // Batched arena: one BLOCK of activations plus the full-batch
    // output, all reused across calls (the input rows are read in
    // place — `&[[f32; FEAT_DIM]]` is already a contiguous row-major
    // matrix).
    bh1: Vec<f32>,
    bh2: Vec<f32>,
    by: Vec<f32>,
    out: Vec<(f32, f32)>,
}

impl NativeMlp {
    pub fn new(weights: MlpWeights) -> NativeMlp {
        assert!(weights.shapes_ok());
        NativeMlp {
            weights,
            epoch: next_weight_epoch(),
            h1: vec![0.0; HIDDEN1],
            h2: vec![0.0; HIDDEN2],
            y: vec![0.0; OUT_DIM],
            bh1: vec![0.0; BLOCK * HIDDEN1],
            bh2: vec![0.0; BLOCK * HIDDEN2],
            by: vec![0.0; BLOCK * OUT_DIM],
            out: Vec::new(),
        }
    }

    pub fn weights(&self) -> &MlpWeights {
        &self.weights
    }

    /// Swap in new parameters and advance the weight epoch — cached
    /// worker clones of the old weights become stale and are
    /// re-cloned lazily on the next pooled fan-out.
    pub fn set_weights(&mut self, weights: MlpWeights) {
        assert!(weights.shapes_ok());
        self.weights = weights;
        self.epoch = next_weight_epoch();
    }

    /// Forward one feature vector; returns the raw (y0, y1) pair.
    pub fn forward(&mut self, f: &[f32; FEAT_DIM]) -> (f32, f32) {
        dense(f, &self.weights.w1, &self.weights.b1, HIDDEN1, true, &mut self.h1);
        dense(&self.h1, &self.weights.w2, &self.weights.b2, HIDDEN2, true, &mut self.h2);
        dense(&self.h2, &self.weights.w3, &self.weights.b3, OUT_DIM, false, &mut self.y);
        // Output activation: softplus keeps both outputs positive and
        // smooth (must match model.py).
        (softplus(self.y[0]), softplus(self.y[1]))
    }

    /// Forward a whole batch through the blocked GEMM pipeline;
    /// returns one raw (y0, y1) pair per input row, bit-identical to
    /// calling [`NativeMlp::forward`] row by row. The returned slice
    /// borrows the internal arena — no allocation at steady state.
    pub fn forward_batch(&mut self, feats: &[[f32; FEAT_DIM]]) -> &[(f32, f32)] {
        self.out.clear();
        self.out.reserve(feats.len());
        for chunk in feats.chunks(BLOCK) {
            let rows = chunk.len();
            dense_batch(
                chunk.as_flattened(),
                FEAT_DIM,
                &self.weights.w1,
                &self.weights.b1,
                HIDDEN1,
                true,
                &mut self.bh1[..rows * HIDDEN1],
            );
            dense_batch(
                &self.bh1[..rows * HIDDEN1],
                HIDDEN1,
                &self.weights.w2,
                &self.weights.b2,
                HIDDEN2,
                true,
                &mut self.bh2[..rows * HIDDEN2],
            );
            dense_batch(
                &self.bh2[..rows * HIDDEN2],
                HIDDEN2,
                &self.weights.w3,
                &self.weights.b3,
                OUT_DIM,
                false,
                &mut self.by[..rows * OUT_DIM],
            );
            for yr in self.by[..rows * OUT_DIM].chunks_exact(OUT_DIM) {
                self.out.push((softplus(yr[0]), softplus(yr[1])));
            }
        }
        &self.out
    }
}

#[inline]
pub fn softplus(x: f32) -> f32 {
    // Numerically stable: log1p(exp(-|x|)) + max(x, 0).
    let ax = (-x.abs()).exp();
    ax.ln_1p() + x.max(0.0)
}

impl EnergyPredictor for NativeMlp {
    fn name(&self) -> &'static str {
        "native-mlp"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        let mut out = Vec::with_capacity(feats.len());
        self.predict_into(feats, &mut out);
        out
    }

    fn predict_into(&mut self, feats: &[[f32; FEAT_DIM]], out: &mut Vec<Prediction>) {
        out.clear();
        out.reserve(feats.len());
        for &(y0, y1) in self.forward_batch(feats) {
            out.push(decode_output(y0, y1));
        }
    }

    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        // The clone carries the same weights and its own arena; the
        // kernels are deterministic, so clone scoring is bit-identical
        // to the original (asserted in the tests below).
        Some(Box::new(self.clone()))
    }

    fn weight_epoch(&self) -> u64 {
        self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn forward_is_deterministic_and_finite() {
        let mut m = NativeMlp::new(MlpWeights::init(7));
        let f = [0.3f32; FEAT_DIM];
        let a = m.forward(&f);
        let b = m.forward(&f);
        assert_eq!(a, b);
        assert!(a.0.is_finite() && a.1.is_finite());
        assert!(a.0 >= 0.0 && a.1 >= 0.0, "softplus outputs nonneg");
    }

    #[test]
    fn dense_matches_manual_computation() {
        // 2×3 layer: x=[1,2], w=[[1,2,3],[4,5,6]], b=[0.5,0.5,0.5].
        let x = [1.0f32, 2.0];
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5f32; 3];
        let mut out = [0.0f32; 3];
        dense(&x, &w, &b, 3, false, &mut out);
        assert_eq!(out, [9.5, 12.5, 15.5]);
    }

    #[test]
    fn dense_handles_zero_inputs_branch_free() {
        // A zero input contributes nothing but is still accumulated —
        // same result as the manual computation, constant FLOPs.
        let x = [0.0f32, 2.0];
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5f32; 3];
        let mut out = [0.0f32; 3];
        dense(&x, &w, &b, 3, false, &mut out);
        assert_eq!(out, [8.5, 10.5, 12.5]);
    }

    #[test]
    fn dense_batch_runs_rows_independently() {
        // Two rows through a 2×3 layer equal two single-row calls.
        let x = [1.0f32, 2.0, 0.5, -1.0];
        let w = [1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = [0.5f32; 3];
        let mut y = [0.0f32; 6];
        dense_batch(&x, 2, &w, &b, 3, false, &mut y);
        let mut row = [0.0f32; 3];
        dense(&x[..2], &w, &b, 3, false, &mut row);
        assert_eq!(&y[..3], &row);
        dense(&x[2..], &w, &b, 3, false, &mut row);
        assert_eq!(&y[3..], &row);
    }

    #[test]
    fn relu_clamps() {
        let x = [1.0f32];
        let w = [-5.0f32, 5.0];
        let b = [0.0f32; 2];
        let mut out = [0.0f32; 2];
        dense(&x, &w, &b, 2, true, &mut out);
        assert_eq!(out, [0.0, 5.0]);
    }

    #[test]
    fn softplus_properties() {
        assert!((softplus(0.0) - 0.6931472).abs() < 1e-6);
        assert!(softplus(-30.0) < 1e-9);
        assert!((softplus(30.0) - 30.0).abs() < 1e-6);
        // Monotone.
        assert!(softplus(1.0) > softplus(0.5));
    }

    #[test]
    fn batch_matches_single() {
        let mut m = NativeMlp::new(MlpWeights::init(9));
        let f1 = [0.1f32; FEAT_DIM];
        let mut f2 = [0.0f32; FEAT_DIM];
        f2[0] = 0.9;
        let batch = m.predict(&[f1, f2]);
        let (y0, _) = m.forward(&f1);
        assert!((batch[0].power_w - y0 as f64 * 100.0).abs() < 1e-4);
        assert_ne!(batch[0], batch[1]);
    }

    #[test]
    fn forward_batch_bit_identical_to_forward() {
        let mut rng = Xoshiro256::seed_from_u64(31);
        let mut m = NativeMlp::new(MlpWeights::init(31));
        // Rows with exact zeros exercise the branch-free accumulation.
        let feats: Vec<[f32; FEAT_DIM]> = (0..BLOCK + 5)
            .map(|_| {
                let mut f = [0f32; FEAT_DIM];
                for x in f.iter_mut() {
                    *x = if rng.chance(0.25) {
                        0.0
                    } else {
                        rng.uniform(-1.0, 2.0) as f32
                    };
                }
                f
            })
            .collect();
        let singles: Vec<(f32, f32)> = feats.iter().map(|f| m.forward(f)).collect();
        let batched = m.forward_batch(&feats).to_vec();
        assert_eq!(batched, singles, "batched path must be bit-identical");
    }

    #[test]
    fn predict_into_reuses_buffer_and_matches_predict() {
        let mut m = NativeMlp::new(MlpWeights::init(5));
        let feats = vec![[0.4f32; FEAT_DIM]; 10];
        let fresh = m.predict(&feats);
        let mut buf = vec![Prediction { power_w: -1.0, slowdown: -1.0 }; 3];
        m.predict_into(&feats, &mut buf);
        assert_eq!(buf, fresh);
    }

    #[test]
    fn try_clone_scores_bit_identical() {
        let mut m = NativeMlp::new(MlpWeights::init(11));
        let feats = vec![[0.3f32; FEAT_DIM]; 7];
        let mine = m.predict(&feats);
        let mut clone = m.try_clone().expect("native mlp is cloneable");
        assert_eq!(clone.predict(&feats), mine);
        assert_eq!(clone.name(), "native-mlp");
    }

    #[test]
    fn set_weights_changes_outputs() {
        let mut m = NativeMlp::new(MlpWeights::init(1));
        let f = [0.5f32; FEAT_DIM];
        let before = m.forward_batch(&[f])[0];
        m.set_weights(MlpWeights::init(2));
        let after = m.forward_batch(&[f])[0];
        assert_ne!(before, after);
        // Batched path still agrees with the single-row path.
        assert_eq!(after, m.forward(&f));
    }

    #[test]
    fn weight_epoch_tracks_set_weights_and_survives_clone() {
        let mut m = NativeMlp::new(MlpWeights::init(1));
        let other = NativeMlp::new(MlpWeights::init(1));
        let e0 = m.weight_epoch();
        assert_ne!(e0, 0, "instance epochs never collide with the stateless default");
        assert_ne!(e0, other.weight_epoch(), "epochs are instance-unique");
        // A clone carries the same weights → the same epoch.
        let clone = m.try_clone().unwrap();
        assert_eq!(clone.weight_epoch(), e0);
        // New weights → new epoch; the old clone is now stale.
        m.set_weights(MlpWeights::init(2));
        assert_ne!(m.weight_epoch(), e0);
        assert_eq!(clone.weight_epoch(), e0);
    }

    #[test]
    fn different_inputs_differ() {
        let mut m = NativeMlp::new(MlpWeights::init(3));
        let a = m.forward(&[0.0f32; FEAT_DIM]);
        let b = m.forward(&[1.0f32; FEAT_DIM]);
        assert_ne!(a, b);
    }
}
