//! The prediction engine (§III-B, Eq. 4): swappable estimators of a
//! placement's energy and SLA impact, plus training infrastructure.

pub mod dataset;
pub mod dtree;
pub mod engine;
pub mod linear;
pub mod native_mlp;
pub mod oracle;
pub mod trainer;
pub mod xla_mlp;

pub use dataset::{synthesize, Dataset};
pub use dtree::{DecisionTree, TreeParams, TreePredictor};
pub use engine::{next_weight_epoch, EnergyPredictor, MlpWeights, Prediction, POWER_SCALE};
pub use linear::{LinearModel, LinearPredictor};
pub use native_mlp::NativeMlp;
pub use oracle::{oracle_eval, OraclePredictor};
pub use trainer::{TrainReport, Trainer};
pub use xla_mlp::XlaMlp;
