//! Training driver: Rust owns the epoch loop, shuffling, and weight
//! persistence; the gradients + Adam update run inside the AOT
//! `train_step.hlo.txt` (L2's `train_step` function — forward, MSE
//! loss, backward, parameter update in one fused XLA program).

use crate::predict::dataset::Dataset;
use crate::predict::engine::{MlpWeights, HIDDEN1, HIDDEN2, OUT_DIM};
use crate::profile::FEAT_DIM;
use crate::runtime::{Runtime, RuntimeError};
use crate::util::rng::Xoshiro256;

/// Adam state mirrors the parameter shapes.
#[derive(Debug, Clone)]
struct AdamState {
    m: [Vec<f32>; 6],
    v: [Vec<f32>; 6],
    step: f32,
}

impl AdamState {
    fn zeros() -> AdamState {
        let sizes = [
            FEAT_DIM * HIDDEN1,
            HIDDEN1,
            HIDDEN1 * HIDDEN2,
            HIDDEN2,
            HIDDEN2 * OUT_DIM,
            OUT_DIM,
        ];
        AdamState {
            m: sizes.map(|n| vec![0.0; n]),
            v: sizes.map(|n| vec![0.0; n]),
            step: 0.0,
        }
    }
}

/// Result of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub epochs: usize,
    pub steps: u64,
    /// Minibatch loss per epoch (mean).
    pub loss_curve: Vec<f64>,
    /// Validation MSE after training (raw-output space).
    pub val_mse: f64,
}

/// Trains `f_θ` through the `train_step` artifact.
pub struct Trainer {
    runtime: Runtime,
    pub weights: MlpWeights,
    adam: AdamState,
}

impl Trainer {
    pub fn new(mut runtime: Runtime, init: MlpWeights) -> Result<Trainer, RuntimeError> {
        assert!(init.shapes_ok());
        runtime.load("train_step")?;
        Ok(Trainer {
            runtime,
            weights: init,
            adam: AdamState::zeros(),
        })
    }

    /// One minibatch step; returns the loss.
    fn step(&mut self, feats: &[f32], targets: &[f32]) -> Result<f64, RuntimeError> {
        let tb = self.runtime.meta.train_batch;
        assert_eq!(feats.len(), tb * FEAT_DIM);
        assert_eq!(targets.len(), tb * 2);
        self.adam.step += 1.0;
        let step_arr = [self.adam.step];
        let param_shapes: [[i64; 2]; 6] = [
            [FEAT_DIM as i64, HIDDEN1 as i64],
            [1, HIDDEN1 as i64],
            [HIDDEN1 as i64, HIDDEN2 as i64],
            [1, HIDDEN2 as i64],
            [HIDDEN2 as i64, OUT_DIM as i64],
            [1, OUT_DIM as i64],
        ];
        let feats_shape = [tb as i64, FEAT_DIM as i64];
        let targets_shape = [tb as i64, 2];
        let scalar_shape = [1i64, 1];
        let params = self.weights.as_ordered();

        let mut inputs: Vec<(&[f32], &[i64])> = Vec::with_capacity(21);
        for ((data, _), shape) in params.iter().zip(param_shapes.iter()) {
            inputs.push((data, shape));
        }
        for i in 0..6 {
            inputs.push((&self.adam.m[i], &param_shapes[i]));
        }
        for i in 0..6 {
            inputs.push((&self.adam.v[i], &param_shapes[i]));
        }
        inputs.push((&step_arr, &scalar_shape));
        inputs.push((feats, &feats_shape));
        inputs.push((targets, &targets_shape));

        let out = self.runtime.execute_f32("train_step", &inputs)?;
        assert_eq!(out.len(), 19, "train_step must return 19 tensors");
        self.weights.w1 = out[0].clone();
        self.weights.b1 = out[1].clone();
        self.weights.w2 = out[2].clone();
        self.weights.b2 = out[3].clone();
        self.weights.w3 = out[4].clone();
        self.weights.b3 = out[5].clone();
        for i in 0..6 {
            self.adam.m[i] = out[6 + i].clone();
            self.adam.v[i] = out[12 + i].clone();
        }
        Ok(out[18][0] as f64)
    }

    /// Full training loop with shuffled fixed-size minibatches (the
    /// tail that doesn't fill a batch is dropped — shapes are baked
    /// into the artifact).
    pub fn train(
        &mut self,
        train: &Dataset,
        val: &Dataset,
        epochs: usize,
        seed: u64,
    ) -> Result<TrainReport, RuntimeError> {
        let tb = self.runtime.meta.train_batch;
        assert!(
            train.len() >= tb,
            "training set ({}) smaller than train_batch ({tb})",
            train.len()
        );
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut order: Vec<usize> = (0..train.len()).collect();
        let mut loss_curve = Vec::with_capacity(epochs);
        let mut steps = 0u64;
        let mut fbuf = vec![0f32; tb * FEAT_DIM];
        let mut tbuf = vec![0f32; tb * 2];
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut epoch_loss = 0.0;
            let mut n_batches = 0;
            for chunk in order.chunks_exact(tb) {
                for (row, &idx) in chunk.iter().enumerate() {
                    fbuf[row * FEAT_DIM..(row + 1) * FEAT_DIM]
                        .copy_from_slice(&train.xs[idx]);
                    tbuf[row * 2..(row + 1) * 2].copy_from_slice(&train.ys[idx]);
                }
                epoch_loss += self.step(&fbuf, &tbuf)?;
                n_batches += 1;
                steps += 1;
            }
            loss_curve.push(epoch_loss / n_batches.max(1) as f64);
        }
        // Validation through the native forward (same weights; f32
        // parity with the XLA path is asserted in integration tests).
        let mut native = crate::predict::native_mlp::NativeMlp::new(self.weights.clone());
        let val_mse = val.mse(|x| {
            let (a, b) = native.forward(x);
            [a, b]
        });
        Ok(TrainReport {
            epochs,
            steps,
            loss_curve,
            val_mse,
        })
    }
}

// Trainer tests require artifacts; see rust/tests/runtime_xla.rs.
