//! Ridge-regularized linear model — the simplest learned predictor in
//! the `abl2` ablation. Fit by solving the normal equations
//! (XᵀX + λI)·w = Xᵀy with Gaussian elimination (from scratch: no
//! linear-algebra crates in the offline set).

use crate::predict::engine::{decode_output, next_weight_epoch, EnergyPredictor, Prediction};
use crate::profile::FEAT_DIM;

/// One ridge model per output, plus intercepts.
#[derive(Debug, Clone)]
pub struct LinearModel {
    /// [FEAT_DIM + 1] coefficients per output (last = intercept).
    pub coef: [[f64; FEAT_DIM + 1]; 2],
}

impl LinearModel {
    /// Fit via the normal equations with ridge penalty `lambda`.
    pub fn fit(xs: &[[f32; FEAT_DIM]], ys: &[[f32; 2]], lambda: f64) -> LinearModel {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        const D: usize = FEAT_DIM + 1;
        // Accumulate XᵀX and Xᵀy with the bias column folded in.
        let mut xtx = [[0f64; D]; D];
        let mut xty = [[0f64; D]; 2];
        let mut row = [0f64; D];
        for (x, y) in xs.iter().zip(ys) {
            for i in 0..FEAT_DIM {
                row[i] = x[i] as f64;
            }
            row[FEAT_DIM] = 1.0;
            for i in 0..D {
                for j in 0..D {
                    xtx[i][j] += row[i] * row[j];
                }
                xty[0][i] += row[i] * y[0] as f64;
                xty[1][i] += row[i] * y[1] as f64;
            }
        }
        for (i, r) in xtx.iter_mut().enumerate().take(FEAT_DIM) {
            r[i] += lambda; // don't penalize the intercept
        }
        let coef0 = solve(&xtx, &xty[0]);
        let coef1 = solve(&xtx, &xty[1]);
        LinearModel {
            coef: [coef0, coef1],
        }
    }

    pub fn eval(&self, x: &[f32; FEAT_DIM]) -> [f32; 2] {
        let mut out = [0f32; 2];
        for (o, c) in out.iter_mut().zip(&self.coef) {
            let mut acc = c[FEAT_DIM];
            for i in 0..FEAT_DIM {
                acc += c[i] * x[i] as f64;
            }
            *o = acc as f32;
        }
        out
    }
}

/// Solve A·w = b by Gaussian elimination with partial pivoting.
fn solve(a: &[[f64; FEAT_DIM + 1]; FEAT_DIM + 1], b: &[f64; FEAT_DIM + 1]) -> [f64; FEAT_DIM + 1] {
    const D: usize = FEAT_DIM + 1;
    let mut m = *a;
    let mut v = *b;
    for col in 0..D {
        // Pivot.
        let mut piv = col;
        for r in col + 1..D {
            if m[r][col].abs() > m[piv][col].abs() {
                piv = r;
            }
        }
        m.swap(col, piv);
        v.swap(col, piv);
        let diag = m[col][col];
        if diag.abs() < 1e-12 {
            continue; // singular direction: leave zero (ridge prevents this)
        }
        for r in col + 1..D {
            let k = m[r][col] / diag;
            if k == 0.0 {
                continue;
            }
            for c in col..D {
                m[r][c] -= k * m[col][c];
            }
            v[r] -= k * v[col];
        }
    }
    // Back-substitution.
    let mut w = [0f64; D];
    for col in (0..D).rev() {
        let mut acc = v[col];
        for c in col + 1..D {
            acc -= m[col][c] * w[c];
        }
        w[col] = if m[col][col].abs() < 1e-12 {
            0.0
        } else {
            acc / m[col][col]
        };
    }
    w
}

pub struct LinearPredictor {
    model: LinearModel,
    /// Instance-unique weight epoch — the model is fixed at
    /// construction, but two instances may carry different fits, so
    /// cached worker clones must never be shared across them.
    epoch: u64,
}

impl LinearPredictor {
    pub fn new(model: LinearModel) -> LinearPredictor {
        LinearPredictor {
            model,
            epoch: next_weight_epoch(),
        }
    }
}

impl EnergyPredictor for LinearPredictor {
    fn name(&self) -> &'static str {
        "linear"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        feats
            .iter()
            .map(|f| {
                let y = self.model.eval(f);
                decode_output(y[0], y[1])
            })
            .collect()
    }

    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        Some(Box::new(LinearPredictor {
            model: self.model.clone(),
            epoch: self.epoch,
        }))
    }

    fn weight_epoch(&self) -> u64 {
        self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    #[test]
    fn recovers_linear_ground_truth() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..500 {
            let mut x = [0f32; FEAT_DIM];
            for v in x.iter_mut() {
                *v = rng.next_f64() as f32;
            }
            // y0 = 0.3 + 2·x0 − x5 ; y1 = 0.1 + 0.5·x8.
            ys.push([
                0.3 + 2.0 * x[0] - x[5],
                0.1 + 0.5 * x[8],
            ]);
            xs.push(x);
        }
        let m = LinearModel::fit(&xs, &ys, 1e-6);
        assert!((m.coef[0][0] - 2.0).abs() < 1e-3, "{}", m.coef[0][0]);
        assert!((m.coef[0][5] + 1.0).abs() < 1e-3);
        assert!((m.coef[0][FEAT_DIM] - 0.3).abs() < 1e-3);
        assert!((m.coef[1][8] - 0.5).abs() < 1e-3);
        // Predictions match.
        let p = m.eval(&xs[0]);
        assert!((p[0] - ys[0][0]).abs() < 1e-3);
    }

    #[test]
    fn ridge_shrinks_coefficients() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..100 {
            let mut x = [0f32; FEAT_DIM];
            for v in x.iter_mut() {
                *v = rng.next_f64() as f32;
            }
            ys.push([3.0 * x[0], 0.0]);
            xs.push(x);
        }
        let loose = LinearModel::fit(&xs, &ys, 1e-9);
        let tight = LinearModel::fit(&xs, &ys, 1e3);
        assert!(tight.coef[0][0].abs() < loose.coef[0][0].abs());
    }

    #[test]
    fn handles_duplicate_rows() {
        // Rank-deficient X (all rows identical): ridge keeps it solvable.
        let xs = vec![[0.5f32; FEAT_DIM]; 30];
        let ys = vec![[1.0f32, 0.5]; 30];
        let m = LinearModel::fit(&xs, &ys, 1e-3);
        let p = m.eval(&[0.5; FEAT_DIM]);
        assert!((p[0] - 1.0).abs() < 0.05);
    }

    #[test]
    fn predictor_interface() {
        let xs = vec![[0.1f32; FEAT_DIM]; 10];
        let ys = vec![[0.4f32, 0.2]; 10];
        let mut p = LinearPredictor::new(LinearModel::fit(&xs, &ys, 1e-3));
        let out = p.predict(&xs[..3]);
        assert_eq!(out.len(), 3);
        assert_eq!(p.name(), "linear");
        assert!((out[0].power_w - 40.0).abs() < 5.0);
    }
}
