//! XLA-backed MLP predictor — the production `f_θ` path: executes the
//! AOT-compiled `predict.hlo.txt` (L2 JAX model wrapping the L1 Pallas
//! scoring kernel) through the PJRT CPU client.
//!
//! Batching: the artifact is compiled for a fixed batch `meta.batch`;
//! calls with fewer rows are padded (scores for padding rows are
//! discarded), larger batches run in chunks.

use crate::predict::engine::{
    decode_output, next_weight_epoch, EnergyPredictor, MlpWeights, Prediction,
};
use crate::profile::FEAT_DIM;
use crate::runtime::{Runtime, RuntimeError};

pub struct XlaMlp {
    runtime: Runtime,
    weights: MlpWeights,
    /// Weight epoch, advanced by `set_weights` (the engine is not
    /// cloneable — `try_clone` is `None` — so nothing caches by it
    /// today, but the epoch contract holds across every predictor).
    epoch: u64,
    batch: usize,
    /// Reused padded input buffer.
    buf: Vec<f32>,
    /// Weights staged on the device once per `set_weights` — model
    /// parameters don't change between decisions, and re-uploading
    /// them dominated dispatch cost (§Perf iteration 1).
    weight_bufs: Vec<xla::PjRtBuffer>,
}

impl XlaMlp {
    /// Build from a runtime and trained weights. Preloads + compiles
    /// the `predict` executable eagerly so the first scheduling
    /// decision doesn't pay compile latency.
    pub fn new(mut runtime: Runtime, weights: MlpWeights) -> Result<XlaMlp, RuntimeError> {
        assert!(weights.shapes_ok());
        runtime.load("predict")?;
        let batch = runtime.meta.batch;
        let mut this = XlaMlp {
            runtime,
            weights,
            epoch: next_weight_epoch(),
            batch,
            buf: vec![0.0; 0],
            weight_bufs: Vec::new(),
        };
        this.stage_weights()?;
        Ok(this)
    }

    /// Upload the six parameter tensors to the device.
    fn stage_weights(&mut self) -> Result<(), RuntimeError> {
        self.weight_bufs.clear();
        for (data, shape) in self.weights.as_ordered() {
            self.weight_bufs.push(
                self.runtime
                    .buffer_f32(data, &[shape[0] as usize, shape[1] as usize])?,
            );
        }
        Ok(())
    }

    /// Load weights from `artifacts/weights.json` (trained via
    /// `ecosched train`), falling back to a deterministic init when the
    /// file is absent.
    pub fn from_artifacts(dir: &std::path::Path) -> Result<XlaMlp, RuntimeError> {
        let runtime = Runtime::new(dir)?;
        let weights =
            MlpWeights::load(&dir.join("weights.json")).unwrap_or_else(|| MlpWeights::init(42));
        XlaMlp::new(runtime, weights)
    }

    pub fn weights(&self) -> &MlpWeights {
        &self.weights
    }

    pub fn set_weights(&mut self, w: MlpWeights) {
        assert!(w.shapes_ok());
        self.weights = w;
        self.epoch = next_weight_epoch();
        self.stage_weights().expect("re-staging weights failed");
    }

    pub fn exec_count(&self) -> u64 {
        self.runtime.exec_count
    }

    /// Score one padded chunk of exactly `self.batch` rows, appending
    /// the decoded predictions to `out`. Only the feature tensor is
    /// uploaded; the staged weight buffers are reused.
    fn run_chunk(
        &mut self,
        chunk: &[[f32; FEAT_DIM]],
        out: &mut Vec<Prediction>,
    ) -> Result<(), RuntimeError> {
        debug_assert!(chunk.len() <= self.batch);
        let rows = chunk.len();
        self.buf.clear();
        self.buf.extend_from_slice(chunk.as_flattened());
        self.buf.resize(self.batch * FEAT_DIM, 0.0);
        let feats_buf = self
            .runtime
            .buffer_f32(&self.buf, &[self.batch, FEAT_DIM])?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(7);
        args.push(&feats_buf);
        for b in &self.weight_bufs {
            args.push(b);
        }
        let result = self.runtime.execute_buffers("predict", &args)?;
        let y = &result[0]; // [batch, 2] flattened
        debug_assert_eq!(y.len(), self.batch * 2);
        out.extend(
            y[..rows * 2]
                .chunks_exact(2)
                .map(|p| decode_output(p[0], p[1])),
        );
        Ok(())
    }

    /// Fallible batched scoring into a caller-provided buffer
    /// (cleared first) — the allocation-free path `predict_into`
    /// wraps.
    pub fn try_predict_into(
        &mut self,
        feats: &[[f32; FEAT_DIM]],
        out: &mut Vec<Prediction>,
    ) -> Result<(), RuntimeError> {
        out.clear();
        out.reserve(feats.len());
        for chunk in feats.chunks(self.batch) {
            if let Err(e) = self.run_chunk(chunk, out) {
                // Never hand back a partial prediction vector — a
                // caller that recovers from the error must not pair
                // stale rows with fresh features.
                out.clear();
                return Err(e);
            }
        }
        Ok(())
    }

    /// Fallible batched scoring.
    pub fn try_predict(
        &mut self,
        feats: &[[f32; FEAT_DIM]],
    ) -> Result<Vec<Prediction>, RuntimeError> {
        let mut out = Vec::with_capacity(feats.len());
        self.try_predict_into(feats, &mut out)?;
        Ok(out)
    }
}

impl EnergyPredictor for XlaMlp {
    fn name(&self) -> &'static str {
        "xla-mlp"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        // The runtime is loaded and validated at construction; an
        // execution error here is unrecoverable misconfiguration.
        self.try_predict(feats).expect("predict.hlo execution failed")
    }

    fn predict_into(&mut self, feats: &[[f32; FEAT_DIM]], out: &mut Vec<Prediction>) {
        self.try_predict_into(feats, out)
            .expect("predict.hlo execution failed")
    }

    fn weight_epoch(&self) -> u64 {
        self.epoch
    }
}

// XLA-path tests require `make artifacts`; they live in
// rust/tests/runtime_xla.rs together with the native-vs-XLA parity
// check.
