//! Analytic oracle: the exact marginal-power and slowdown a placement
//! produces under the simulator's own physics (Eq. 5 power model +
//! contention semantics). Three roles:
//!
//! 1. **Label source** for training `f_θ` — the paper trains on
//!    "historical execution outcomes"; our calibration campaigns are
//!    summarized by this closed form (it is what those outcomes
//!    converge to in expectation).
//! 2. **Upper-bound predictor** in the `abl2` ablation (how much of
//!    the oracle's decision quality does the learned model recover?).
//! 3. **Ground truth** for predictor accuracy tests.

use crate::predict::engine::{EnergyPredictor, Prediction};
use crate::profile::FEAT_DIM;

/// Feature-index constants (see `profile::features` for the layout).
const W_CPU: usize = 0;
const W_MEM: usize = 1;
const W_DISK: usize = 2;
const W_NET: usize = 3;
const H_CPU: usize = 8;
const H_MEM: usize = 9;
const H_DISK: usize = 10;
const H_NET: usize = 11;
const H_FREQ: usize = 13;

/// Flavor-to-host capacity ratios for the MEDIUM worker on the paper
/// testbed host: how much host utilization one unit of normalized
/// workload demand adds.
pub const RATIO_CPU: f64 = 8.0 / 32.0;
pub const RATIO_MEM: f64 = 16.0 / 64.0;
pub const RATIO_DISK: f64 = 200.0 / 1000.0;
pub const RATIO_NET: f64 = 60.0 / 117.0;

/// Power coefficients mirrored from `cluster::power::XEON_64GB`.
const ALPHA: f64 = 140.0;
const BETA: f64 = 16.0;
const GAMMA: f64 = 14.0;

/// Post-placement utilization estimate (cpu, mem, disk, net) for a
/// MEDIUM worker on the testbed host — shared by the energy-aware
/// policy's headroom filter and the consolidation planner.
pub fn post_utilization(
    w: &crate::profile::ResourceVector,
    u: &crate::cluster::Utilization,
) -> (f64, f64, f64, f64) {
    (
        u.cpu + w.cpu * RATIO_CPU,
        u.mem + w.mem * RATIO_MEM,
        u.disk + w.disk * RATIO_DISK,
        u.net + w.net * RATIO_NET,
    )
}

/// Closed-form marginal power (W) and slowdown for one feature vector.
pub fn oracle_eval(f: &[f32; FEAT_DIM]) -> Prediction {
    let w_cpu = f[W_CPU] as f64;
    let w_mem = f[W_MEM] as f64;
    let w_disk = f[W_DISK] as f64;
    let w_net = f[W_NET] as f64;
    let h_cpu = f[H_CPU] as f64;
    let h_mem = f[H_MEM] as f64;
    let h_disk = f[H_DISK] as f64;
    let h_net = f[H_NET] as f64;
    let freq = (f[H_FREQ] as f64).clamp(0.6, 1.0);

    // New utilizations after placement (clamped at capacity).
    let n_cpu = (h_cpu + w_cpu * RATIO_CPU).min(1.0);
    let n_mem = (h_mem + w_mem * RATIO_MEM).min(1.0);
    let n_disk = (h_disk + w_disk * RATIO_DISK).min(1.0);
    let n_net = (h_net + w_net * RATIO_NET).min(1.0);

    // Eq. 5 delta. I/O enters as max(disk, net), matching Host::power.
    let cpu_scale = 0.3 + 0.7 * freq * freq;
    let d_power = ALPHA * cpu_scale * (n_cpu - h_cpu)
        + BETA * (n_mem - h_mem)
        + GAMMA * (n_disk.max(n_net) - h_disk.max(h_net));

    // Slowdown: per-dimension oversubscription after placement.
    // Total demand in host units ≈ new_util unclamped:
    let t_cpu = h_cpu + w_cpu * RATIO_CPU / freq.max(1e-6); // DVFS shrinks CPU capacity
    let t_mem = h_mem + w_mem * RATIO_MEM;
    let t_disk = h_disk + w_disk * RATIO_DISK;
    let t_net = h_net + w_net * RATIO_NET;
    let mut rate: f64 = 1.0;
    // A dimension gates the job only if the workload actually uses it
    // (mirrors Phase::progress_rate's demand thresholds).
    if w_cpu > 0.025 && t_cpu > 1.0 {
        rate = rate.min(1.0 / t_cpu);
    }
    if w_mem > 0.03 && t_mem > 1.0 {
        rate = rate.min(1.0 / t_mem);
    }
    if w_disk > 0.025 && t_disk > 1.0 {
        rate = rate.min(1.0 / t_disk);
    }
    if w_net > 0.03 && t_net > 1.0 {
        rate = rate.min(1.0 / t_net);
    }
    Prediction {
        power_w: d_power.max(0.0),
        slowdown: (1.0 / rate - 1.0).clamp(0.0, 2.0),
    }
}

/// The oracle as an [`EnergyPredictor`].
#[derive(Debug, Default)]
pub struct OraclePredictor;

impl EnergyPredictor for OraclePredictor {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        feats.iter().map(oracle_eval).collect()
    }

    fn predict_into(&mut self, feats: &[[f32; FEAT_DIM]], out: &mut Vec<Prediction>) {
        out.clear();
        out.extend(feats.iter().map(oracle_eval));
    }

    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        // Stateless closed form: every clone is the oracle itself.
        Some(Box::new(OraclePredictor))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feat(w: [f64; 4], h: [f64; 4], freq: f64) -> [f32; FEAT_DIM] {
        let mut f = [0f32; FEAT_DIM];
        f[W_CPU] = w[0] as f32;
        f[W_MEM] = w[1] as f32;
        f[W_DISK] = w[2] as f32;
        f[W_NET] = w[3] as f32;
        f[H_CPU] = h[0] as f32;
        f[H_MEM] = h[1] as f32;
        f[H_DISK] = h[2] as f32;
        f[H_NET] = h[3] as f32;
        f[H_FREQ] = freq as f32;
        f
    }

    #[test]
    fn empty_host_no_slowdown() {
        let p = oracle_eval(&feat([0.8, 0.5, 0.2, 0.1], [0.0; 4], 1.0));
        assert_eq!(p.slowdown, 0.0);
        // Marginal power: α·0.8·0.25 + β·0.5·0.25 + γ·max-io contribution.
        assert!(p.power_w > 20.0 && p.power_w < 40.0, "{}", p.power_w);
    }

    #[test]
    fn loaded_host_costs_less_marginal_io_power() {
        // Placing an I/O job on a host already busy with I/O adds less
        // marginal power (the max(d, n) term saturates) — the physical
        // reason consolidation of shuffle-heavy jobs saves energy §V-C.
        let idle = oracle_eval(&feat([0.1, 0.2, 0.9, 0.2], [0.0; 4], 1.0));
        let busy = oracle_eval(&feat([0.1, 0.2, 0.9, 0.2], [0.1, 0.2, 0.95, 0.1], 1.0));
        assert!(busy.power_w < idle.power_w);
    }

    #[test]
    fn cpu_saturation_produces_slowdown() {
        // Host at 90 % CPU + workload adding 0.8*0.25 = 20 % → 1.1×
        // oversubscribed → ~10 % slowdown.
        let p = oracle_eval(&feat([0.8, 0.1, 0.0, 0.0], [0.9, 0.1, 0.0, 0.0], 1.0));
        assert!(
            (p.slowdown - 0.1).abs() < 0.02,
            "slowdown {}",
            p.slowdown
        );
    }

    #[test]
    fn io_job_ignores_cpu_contention() {
        // Pure-I/O workload on a CPU-saturated host: no slowdown.
        let p = oracle_eval(&feat([0.0, 0.1, 0.8, 0.2], [1.0, 0.2, 0.0, 0.0], 1.0));
        assert_eq!(p.slowdown, 0.0);
    }

    #[test]
    fn dvfs_reduces_marginal_power_but_can_slow_cpu_jobs() {
        let full = oracle_eval(&feat([0.9, 0.2, 0.0, 0.0], [0.7, 0.2, 0.0, 0.0], 1.0));
        let scaled = oracle_eval(&feat([0.9, 0.2, 0.0, 0.0], [0.7, 0.2, 0.0, 0.0], 0.6));
        assert!(scaled.power_w < full.power_w);
        assert!(scaled.slowdown > full.slowdown);
        // I/O-bound job: DVFS free (no CPU gating).
        let io_full = oracle_eval(&feat([0.02, 0.1, 0.9, 0.3], [0.1, 0.1, 0.1, 0.1], 1.0));
        let io_scaled = oracle_eval(&feat([0.02, 0.1, 0.9, 0.3], [0.1, 0.1, 0.1, 0.1], 0.6));
        assert_eq!(io_scaled.slowdown, io_full.slowdown);
        assert!(io_scaled.power_w <= io_full.power_w);
    }

    #[test]
    fn slowdown_clamped() {
        let p = oracle_eval(&feat([1.0, 1.0, 1.0, 1.0], [1.0; 4], 0.6));
        assert!(p.slowdown <= 2.0);
    }

    #[test]
    fn predictor_interface_batches() {
        let mut o = OraclePredictor;
        let feats = vec![feat([0.5, 0.5, 0.1, 0.1], [0.2; 4], 1.0); 7];
        let out = o.predict(&feats);
        assert_eq!(out.len(), 7);
        assert!(out.windows(2).all(|w| w[0] == w[1]));
        assert_eq!(o.name(), "oracle");
        // The buffer-reusing path clears stale contents and agrees.
        let mut buf = out.clone();
        buf.push(out[0]);
        o.predict_into(&feats, &mut buf);
        assert_eq!(buf, out);
    }
}
