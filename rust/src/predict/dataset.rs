//! Training-set synthesis for the prediction engine.
//!
//! The paper trains `f_θ` on "historical execution outcomes" (§III-B).
//! Our history store provides the workload side of those outcomes;
//! the *placement* side (which host states were tried) comes from
//! calibration sampling: we draw (workload vector, host state) pairs
//! covering the operating region and label them with the analytic
//! oracle — which is exactly what averaged execution outcomes converge
//! to under the simulator's physics. Real profiles from a
//! [`HistoryStore`] can be mixed in to bias sampling toward workloads
//! actually seen.

use crate::predict::oracle::oracle_eval;
use crate::predict::POWER_SCALE;
use crate::profile::{HistoryStore, ResourceVector, FEAT_DIM};
use crate::util::rng::Xoshiro256;

/// A labeled training set.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub xs: Vec<[f32; FEAT_DIM]>,
    pub ys: Vec<[f32; 2]>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Deterministic split for train/validation.
    pub fn split(&self, train_frac: f64) -> (Dataset, Dataset) {
        let n_train = (self.len() as f64 * train_frac) as usize;
        (
            Dataset {
                xs: self.xs[..n_train].to_vec(),
                ys: self.ys[..n_train].to_vec(),
            },
            Dataset {
                xs: self.xs[n_train..].to_vec(),
                ys: self.ys[n_train..].to_vec(),
            },
        )
    }

    /// Flattened feature/target buffers for the XLA train step.
    pub fn flat(&self) -> (Vec<f32>, Vec<f32>) {
        let mut fx = Vec::with_capacity(self.len() * FEAT_DIM);
        let mut fy = Vec::with_capacity(self.len() * 2);
        for (x, y) in self.xs.iter().zip(&self.ys) {
            fx.extend_from_slice(x);
            fy.extend_from_slice(y);
        }
        (fx, fy)
    }

    /// Mean squared error of a predictor's raw outputs on this set.
    pub fn mse(&self, mut eval: impl FnMut(&[f32; FEAT_DIM]) -> [f32; 2]) -> f64 {
        assert!(!self.is_empty());
        let mut s = 0.0;
        for (x, y) in self.xs.iter().zip(&self.ys) {
            let p = eval(x);
            s += ((p[0] - y[0]) as f64).powi(2) + ((p[1] - y[1]) as f64).powi(2);
        }
        s / self.len() as f64
    }
}

/// Generate `n` oracle-labeled samples. If `history` has records, 60 %
/// of workload vectors are drawn (with noise) from observed profiles.
pub fn synthesize(n: usize, seed: u64, history: Option<&HistoryStore>) -> Dataset {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut ds = Dataset::default();
    let profiles: Vec<ResourceVector> = history
        .map(|h| h.records().iter().map(|r| r.profile).collect())
        .unwrap_or_default();
    for _ in 0..n {
        let w = if !profiles.is_empty() && rng.chance(0.6) {
            // Perturb an observed profile.
            let base = profiles[rng.range(0, profiles.len())];
            ResourceVector {
                cpu: (base.cpu * rng.uniform(0.9, 1.1)).clamp(0.0, 1.0),
                mem: (base.mem * rng.uniform(0.9, 1.1)).clamp(0.0, 1.0),
                disk: (base.disk * rng.uniform(0.9, 1.1)).clamp(0.0, 1.0),
                net: (base.net * rng.uniform(0.9, 1.1)).clamp(0.0, 1.0),
                cpu_peak: base.cpu_peak.clamp(0.0, 1.0),
                io_peak: base.io_peak.clamp(0.0, 1.0),
                burstiness: base.burstiness,
            }
        } else {
            // Cover the whole operating region.
            let cpu = rng.next_f64();
            ResourceVector {
                cpu,
                mem: rng.next_f64(),
                disk: rng.next_f64(),
                net: rng.next_f64(),
                cpu_peak: (cpu + rng.uniform(0.0, 0.3)).min(1.0),
                io_peak: rng.next_f64(),
                burstiness: rng.uniform(0.0, 1.5),
            }
        };
        let mut x = [0f32; FEAT_DIM];
        x[0] = w.cpu as f32;
        x[1] = w.mem as f32;
        x[2] = w.disk as f32;
        x[3] = w.net as f32;
        x[4] = w.cpu_peak as f32;
        x[5] = w.io_peak as f32;
        x[6] = w.burstiness.min(2.0) as f32;
        x[7] = (rng.uniform(0.0, 9000.0f64).ln_1p() / 10.0) as f32;
        // Host state: mixture of idle, moderate, and near-saturated.
        let load = match rng.categorical(&[1.0, 2.0, 1.0]) {
            0 => rng.uniform(0.0, 0.15),
            1 => rng.uniform(0.15, 0.7),
            _ => rng.uniform(0.7, 1.0),
        };
        x[8] = (load * rng.uniform(0.7, 1.3)).clamp(0.0, 1.0) as f32;
        x[9] = (load * rng.uniform(0.5, 1.2)).clamp(0.0, 1.0) as f32;
        x[10] = (load * rng.uniform(0.3, 1.4)).clamp(0.0, 1.0) as f32;
        x[11] = (load * rng.uniform(0.3, 1.4)).clamp(0.0, 1.0) as f32;
        x[12] = (rng.range(0, 7) as f64 / 8.0) as f32;
        x[13] = *[1.0f32, 0.85, 0.7, 0.6]
            .get(rng.range(0, 4))
            .unwrap();
        x[14] = x[0] * x[8];
        x[15] = (x[1] + x[9] - 1.0).max(0.0);
        let label = oracle_eval(&x);
        ds.xs.push(x);
        ds.ys
            .push([(label.power_w / POWER_SCALE) as f32, label.slowdown as f32]);
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthesize_is_deterministic() {
        let a = synthesize(100, 5, None);
        let b = synthesize(100, 5, None);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.len(), 100);
    }

    #[test]
    fn labels_are_in_expected_ranges() {
        let ds = synthesize(2000, 1, None);
        for y in &ds.ys {
            assert!((0.0..=1.0).contains(&(y[0] as f64)), "power {y:?}"); // ≤100 W marginal
            assert!((0.0..=2.0).contains(&(y[1] as f64)), "slowdown {y:?}");
        }
        // Non-degenerate: both targets vary.
        let p: Vec<f64> = ds.ys.iter().map(|y| y[0] as f64).collect();
        let s: Vec<f64> = ds.ys.iter().map(|y| y[1] as f64).collect();
        assert!(crate::util::stats::std_dev(&p) > 0.02);
        assert!(crate::util::stats::std_dev(&s) > 0.02);
    }

    #[test]
    fn split_partitions() {
        let ds = synthesize(100, 2, None);
        let (tr, te) = ds.split(0.8);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        assert_eq!(tr.xs[0], ds.xs[0]);
        assert_eq!(te.xs[0], ds.xs[80]);
    }

    #[test]
    fn flat_layout() {
        let ds = synthesize(3, 3, None);
        let (fx, fy) = ds.flat();
        assert_eq!(fx.len(), 3 * FEAT_DIM);
        assert_eq!(fy.len(), 6);
        assert_eq!(fx[FEAT_DIM], ds.xs[1][0]);
    }

    #[test]
    fn mse_of_oracle_is_zero() {
        let ds = synthesize(200, 4, None);
        let mse = ds.mse(|x| {
            let p = oracle_eval(x);
            [(p.power_w / POWER_SCALE) as f32, p.slowdown as f32]
        });
        assert!(mse < 1e-12);
    }

    #[test]
    fn history_biases_sampling() {
        use crate::profile::ExecutionRecord;
        use crate::workload::WorkloadKind;
        let mut h = HistoryStore::new();
        h.push(ExecutionRecord {
            kind: WorkloadKind::SparkKMeans,
            gb: 10.0,
            profile: ResourceVector {
                cpu: 0.93,
                mem: 0.6,
                disk: 0.05,
                net: 0.05,
                cpu_peak: 0.97,
                io_peak: 0.1,
                burstiness: 0.2,
            },
            jct: 100.0,
            solo: 95.0,
            energy_j: 1000.0,
            host_cpu_mean: 0.5,
        });
        let ds = synthesize(500, 6, Some(&h));
        // Many samples should sit near the observed cpu=0.93 profile.
        let near = ds
            .xs
            .iter()
            .filter(|x| (x[0] - 0.93).abs() < 0.1)
            .count();
        assert!(near > 150, "only {near} near observed profile");
    }
}
