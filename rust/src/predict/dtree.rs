//! CART regression tree — the paper's §III-B literally says "the
//! decision tree ranks candidate hosts based on predicted energy
//! impact and SLA risk", so a from-scratch decision tree is a
//! first-class predictor here, compared against the MLP in `abl2`.
//!
//! Multi-output: one tree predicts both targets (variance reduction
//! summed over outputs), which keeps power and slowdown predictions
//! consistent at the leaves.

use crate::predict::engine::{decode_output, next_weight_epoch, EnergyPredictor, Prediction};
use crate::profile::FEAT_DIM;

/// A fitted tree node.
#[derive(Debug, Clone)]
enum Node {
    Leaf {
        value: [f32; 2],
        /// Training samples that reached this leaf (diagnostics).
        #[allow(dead_code)]
        n: usize,
    },
    Split {
        feature: usize,
        threshold: f32,
        left: usize,  // index into nodes
        right: usize,
    },
}

/// Training hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_split: usize,
    pub min_samples_leaf: usize,
    /// Candidate thresholds per feature (quantile grid).
    pub n_thresholds: usize,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 10,
            min_samples_split: 8,
            min_samples_leaf: 4,
            n_thresholds: 16,
        }
    }
}

/// A fitted regression tree.
#[derive(Debug, Clone)]
pub struct DecisionTree {
    nodes: Vec<Node>,
    pub params: TreeParams,
}

impl DecisionTree {
    /// Fit on rows of (features, [y0, y1]).
    pub fn fit(xs: &[[f32; FEAT_DIM]], ys: &[[f32; 2]], params: TreeParams) -> DecisionTree {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            params,
        };
        let idx: Vec<usize> = (0..xs.len()).collect();
        tree.build(xs, ys, idx, 0);
        tree
    }

    fn build(
        &mut self,
        xs: &[[f32; FEAT_DIM]],
        ys: &[[f32; 2]],
        idx: Vec<usize>,
        depth: usize,
    ) -> usize {
        let node_id = self.nodes.len();
        let mean = mean_of(ys, &idx);
        // Reserve the slot; may be overwritten with a split.
        self.nodes.push(Node::Leaf {
            value: mean,
            n: idx.len(),
        });
        if depth >= self.params.max_depth || idx.len() < self.params.min_samples_split {
            return node_id;
        }
        let parent_sse = sse_of(ys, &idx, &mean);
        if parent_sse < 1e-10 {
            return node_id;
        }
        let mut best: Option<(usize, f32, f64)> = None; // (feature, thr, gain)
        for feature in 0..FEAT_DIM {
            // Quantile-grid thresholds over this node's values.
            let mut vals: Vec<f32> = idx.iter().map(|&i| xs[i][feature]).collect();
            vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
            vals.dedup();
            if vals.len() < 2 {
                continue;
            }
            for k in 1..=self.params.n_thresholds {
                let pos = k * (vals.len() - 1) / (self.params.n_thresholds + 1);
                let thr = vals[pos.min(vals.len() - 2)];
                let (l, r): (Vec<usize>, Vec<usize>) =
                    idx.iter().partition(|&&i| xs[i][feature] <= thr);
                if l.len() < self.params.min_samples_leaf
                    || r.len() < self.params.min_samples_leaf
                {
                    continue;
                }
                let lm = mean_of(ys, &l);
                let rm = mean_of(ys, &r);
                let gain = parent_sse - sse_of(ys, &l, &lm) - sse_of(ys, &r, &rm);
                if gain > best.map(|(_, _, g)| g).unwrap_or(1e-9) {
                    best = Some((feature, thr, gain));
                }
            }
        }
        if let Some((feature, threshold, _)) = best {
            let (l, r): (Vec<usize>, Vec<usize>) =
                idx.iter().partition(|&&i| xs[i][feature] <= threshold);
            let left = self.build(xs, ys, l, depth + 1);
            let right = self.build(xs, ys, r, depth + 1);
            self.nodes[node_id] = Node::Split {
                feature,
                threshold,
                left,
                right,
            };
        }
        node_id
    }

    /// Predict raw (y0, y1) for one feature vector.
    pub fn eval(&self, x: &[f32; FEAT_DIM]) -> [f32; 2] {
        let mut at = 0usize;
        loop {
            match &self.nodes[at] {
                Node::Leaf { value, .. } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    at = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], at: usize) -> usize {
            match &nodes[at] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + walk(nodes, *left).max(walk(nodes, *right)),
            }
        }
        walk(&self.nodes, 0)
    }
}

fn mean_of(ys: &[[f32; 2]], idx: &[usize]) -> [f32; 2] {
    let mut m = [0f64; 2];
    for &i in idx {
        m[0] += ys[i][0] as f64;
        m[1] += ys[i][1] as f64;
    }
    let n = idx.len().max(1) as f64;
    [(m[0] / n) as f32, (m[1] / n) as f32]
}

fn sse_of(ys: &[[f32; 2]], idx: &[usize], mean: &[f32; 2]) -> f64 {
    let mut s = 0.0;
    for &i in idx {
        let d0 = (ys[i][0] - mean[0]) as f64;
        let d1 = (ys[i][1] - mean[1]) as f64;
        s += d0 * d0 + d1 * d1;
    }
    s
}

/// The tree as a scheduler-facing predictor.
pub struct TreePredictor {
    tree: DecisionTree,
    /// Instance-unique weight epoch — the tree is fixed at
    /// construction, but two instances may carry different fits, so
    /// cached worker clones must never be shared across them.
    epoch: u64,
}

impl TreePredictor {
    pub fn new(tree: DecisionTree) -> TreePredictor {
        TreePredictor {
            tree,
            epoch: next_weight_epoch(),
        }
    }
}

impl EnergyPredictor for TreePredictor {
    fn name(&self) -> &'static str {
        "dtree"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        feats
            .iter()
            .map(|f| {
                let y = self.tree.eval(f);
                decode_output(y[0], y[1])
            })
            .collect()
    }

    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        Some(Box::new(TreePredictor {
            tree: self.tree.clone(),
            epoch: self.epoch,
        }))
    }

    fn weight_epoch(&self) -> u64 {
        self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn toy_dataset(n: usize, seed: u64) -> (Vec<[f32; FEAT_DIM]>, Vec<[f32; 2]>) {
        // y0 = step function of feature 0; y1 = linear in feature 8.
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let mut x = [0f32; FEAT_DIM];
            for v in x.iter_mut() {
                *v = rng.next_f64() as f32;
            }
            let y0 = if x[0] > 0.5 { 1.0 } else { 0.2 };
            let y1 = 0.5 * x[8];
            xs.push(x);
            ys.push([y0, y1]);
        }
        (xs, ys)
    }

    #[test]
    fn learns_step_function() {
        let (xs, ys) = toy_dataset(500, 1);
        let tree = DecisionTree::fit(&xs, &ys, TreeParams::default());
        let mut lo = [0.25f32; FEAT_DIM];
        lo[0] = 0.1;
        let mut hi = [0.25f32; FEAT_DIM];
        hi[0] = 0.9;
        assert!((tree.eval(&lo)[0] - 0.2).abs() < 0.1);
        assert!((tree.eval(&hi)[0] - 1.0).abs() < 0.1);
    }

    #[test]
    fn learns_second_output_too() {
        let (xs, ys) = toy_dataset(800, 2);
        let tree = DecisionTree::fit(&xs, &ys, TreeParams::default());
        // Probe deep inside the y0=1.0 region (x0=0.9) so the path is
        // free to split on x8; x0≈0.5 would sit on the step boundary
        // where the tree spends its depth budget refining y0.
        let mut a = [0.5f32; FEAT_DIM];
        a[0] = 0.9;
        a[8] = 0.05;
        let mut b = a;
        b[8] = 0.95;
        assert!(tree.eval(&b)[1] > tree.eval(&a)[1] + 0.15);
    }

    #[test]
    fn respects_depth_limit() {
        let (xs, ys) = toy_dataset(500, 3);
        let tree = DecisionTree::fit(
            &xs,
            &ys,
            TreeParams {
                max_depth: 2,
                ..Default::default()
            },
        );
        assert!(tree.depth() <= 2);
    }

    #[test]
    fn constant_targets_yield_single_leaf() {
        let xs = vec![[0.5f32; FEAT_DIM]; 50];
        let ys = vec![[1.0f32, 2.0]; 50];
        let tree = DecisionTree::fit(&xs, &ys, TreeParams::default());
        assert_eq!(tree.n_nodes(), 1);
        assert_eq!(tree.eval(&[0.0; FEAT_DIM]), [1.0, 2.0]);
    }

    #[test]
    fn min_leaf_enforced() {
        let (xs, ys) = toy_dataset(20, 4);
        let tree = DecisionTree::fit(
            &xs,
            &ys,
            TreeParams {
                min_samples_leaf: 10,
                min_samples_split: 20,
                ..Default::default()
            },
        );
        // 20 samples, min split 20 with min leaf 10: at most one split.
        assert!(tree.n_nodes() <= 3);
    }

    #[test]
    fn predictor_interface() {
        let (xs, ys) = toy_dataset(200, 5);
        let tree = DecisionTree::fit(&xs, &ys, TreeParams::default());
        let mut p = TreePredictor::new(tree);
        let out = p.predict(&xs[..5]);
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|p| p.power_w >= 0.0 && p.slowdown >= 0.0));
        assert_eq!(p.name(), "dtree");
    }

    #[test]
    fn generalizes_on_holdout() {
        let (xs, ys) = toy_dataset(1000, 6);
        let (train_x, test_x) = xs.split_at(800);
        let (train_y, test_y) = ys.split_at(800);
        let tree = DecisionTree::fit(train_x, train_y, TreeParams::default());
        let mse: f64 = test_x
            .iter()
            .zip(test_y)
            .map(|(x, y)| {
                let p = tree.eval(x);
                ((p[0] - y[0]) as f64).powi(2) + ((p[1] - y[1]) as f64).powi(2)
            })
            .sum::<f64>()
            / test_x.len() as f64;
        assert!(mse < 0.02, "holdout mse {mse}");
    }
}

impl DecisionTree {
    /// Debug helper: describe the root split.
    pub fn debug_root(&self) -> String {
        format!("{:?}", self.nodes.first())
    }
}
