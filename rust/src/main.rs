//! `ecosched` launcher: campaigns, paper-experiment reproduction,
//! predictor training, and profiling demos. See `ecosched help`.

use ecosched::cli::{Args, USAGE};
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::exp::{self, ExpContext};
use ecosched::util::table::{fmt_dur, fmt_energy};
use ecosched::workload::{Arrivals, Mix, TraceSpec};
use std::path::PathBuf;

fn main() {
    ecosched::util::logger::init();
    let args = match Args::from_env(2, &["fast", "xla"]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match args.subcommand.first().map(String::as_str) {
        Some("run") => cmd_run(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("train") => cmd_train(&args),
        Some("classify") => cmd_classify(&args),
        Some("help") | None => {
            println!("{USAGE}");
            0
        }
        Some(other) => {
            eprintln!("unknown command '{other}'\n\n{USAGE}");
            2
        }
    };
    std::process::exit(code);
}

fn ctx_from(args: &Args) -> ExpContext {
    let mut ctx = if args.switch("fast") {
        ExpContext::fast()
    } else {
        ExpContext::default()
    };
    if let Ok(seeds) = args.u64_list_or("seeds", &ctx.seeds) {
        ctx.seeds = seeds;
    }
    ctx.out_dir = PathBuf::from(args.str_or("out", "results"));
    ctx.artifacts = PathBuf::from(args.str_or("artifacts", "artifacts"));
    ctx
}

fn cmd_run(args: &Args) -> i32 {
    // Config file first (TOML subset, see util::config); CLI flags
    // override.
    let cfg = match args.opt("config") {
        Some(path) => match ecosched::util::config::Config::load(std::path::Path::new(path)) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => ecosched::util::config::Config::default(),
    };
    let campaign = cfg.table("campaign");
    let policy_name = args
        .str_or("policy", campaign.str("policy", "energy_aware"))
        .to_string();
    let seed = args.u64_or("seed", campaign.u64("seed", 42)).unwrap_or(42);
    let hours = args
        .f64_or("hours", campaign.f64("hours", 2.0))
        .unwrap_or(2.0);
    let n_jobs = args
        .usize_or("jobs", campaign.usize("jobs", 24))
        .unwrap_or(24);
    let n_hosts = args
        .usize_or("hosts", campaign.usize("hosts", 5))
        .unwrap_or(5);
    let ctx = ctx_from(args);

    let policy = if policy_name == "energy_aware" {
        ctx.energy_aware_policy()
    } else {
        match make_policy(&policy_name) {
            Some(p) => p,
            None => {
                eprintln!("unknown policy '{policy_name}'");
                return 2;
            }
        }
    };
    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs,
        arrivals: Arrivals::Poisson {
            mean_gap: hours * 3600.0 / n_jobs as f64 * 0.75,
        },
        horizon: hours * 3600.0,
    }
    .generate(seed);
    let mut coord = Coordinator::new(
        CampaignConfig {
            n_hosts,
            seed,
            ..Default::default()
        },
        policy,
    );
    let r = coord.run(trace);
    println!("policy            : {}", r.policy);
    println!("jobs completed    : {}", r.jobs.len());
    println!("makespan          : {}", fmt_dur(r.makespan));
    println!("energy            : {}", fmt_energy(r.energy_j));
    println!("mean power        : {:.1} W", r.mean_power_w());
    println!("energy / work     : {:.1} J per solo-second", r.j_per_solo_second());
    println!("sla compliance    : {:.1} % ({} violations)", r.sla_compliance * 100.0, r.sla_violations);
    println!("mean jct slowdown : {:+.2} %", r.mean_slowdown * 100.0);
    println!("migrations        : {} (stall {:.1} s)", r.migrations, r.migration_stall_s);
    println!("power cycles      : {} | host-off hours: {:.2}", r.power_cycles, r.host_off_s / 3600.0);
    println!(
        "decision latency  : {:.1} µs mean over {} decisions; controller share {:.4} %",
        r.overhead.per_decision_us(),
        r.overhead.n_decisions,
        r.overhead.cpu_share(r.makespan) * 100.0
    );
    0
}

fn cmd_experiment(args: &Args) -> i32 {
    let id = args
        .subcommand
        .get(1)
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let ctx = ctx_from(args);
    if !ctx.has_artifacts() {
        eprintln!(
            "note: no artifacts at {:?}; predictor falls back to the analytic oracle.\n\
             Run `make artifacts` for the full XLA path.\n",
            ctx.artifacts
        );
    }
    if exp::run(&id, &ctx) {
        0
    } else {
        eprintln!("unknown experiment '{id}'. Known: {:?} + scale, all", exp::ALL);
        2
    }
}

fn cmd_train(args: &Args) -> i32 {
    use ecosched::predict::{synthesize, MlpWeights, Trainer};
    use ecosched::runtime::Runtime;
    let ctx = ctx_from(args);
    let epochs = args.usize_or("epochs", 60).unwrap_or(60);
    let samples = args.usize_or("samples", 4000).unwrap_or(4000);
    if !ctx.has_artifacts() {
        eprintln!("train requires artifacts (run `make artifacts`)");
        return 2;
    }
    let ds = synthesize(samples, 7, None);
    let (train, val) = ds.split(0.9);
    let rt = Runtime::new(&ctx.artifacts).expect("runtime");
    let mut trainer = Trainer::new(rt, MlpWeights::init(42)).expect("trainer");
    let report = trainer.train(&train, &val, epochs, 1).expect("training");
    println!(
        "trained {} epochs ({} steps): loss {:.5} → {:.5}, val MSE {:.6}",
        report.epochs,
        report.steps,
        report.loss_curve.first().unwrap(),
        report.loss_curve.last().unwrap(),
        report.val_mse
    );
    let path = ctx.artifacts.join("weights.json");
    trainer.weights.save(&path).expect("save weights");
    println!("weights → {}", path.display());
    0
}

fn cmd_classify(args: &Args) -> i32 {
    use ecosched::cluster::flavor::MEDIUM;
    use ecosched::profile::{classify, ResourceVector};
    use ecosched::util::rng::Xoshiro256;
    use ecosched::workload::{phases_for, WorkloadKind};
    let n = args.usize_or("jobs", 12).unwrap_or(12);
    let mut rng = Xoshiro256::seed_from_u64(args.u64_or("seed", 42).unwrap_or(42));
    let mix = Mix::paper();
    println!(
        "{:<12} {:>5} {:>6} {:>6} {:>6} {:>6}  class",
        "kind", "gb", "c", "m", "d", "n"
    );
    for _ in 0..n {
        let kind: WorkloadKind = mix.sample(&mut rng);
        let gb = ecosched::workload::tracegen::sample_gb(kind, &mut rng);
        let v = ResourceVector::from_phases(&phases_for(kind, gb, &mut rng), &MEDIUM);
        println!(
            "{:<12} {:>5} {:>6.2} {:>6.2} {:>6.2} {:>6.2}  {}",
            kind.name(),
            gb,
            v.cpu,
            v.mem,
            v.disk,
            v.net,
            classify(&v).name()
        );
    }
    0
}
