//! `fig3` — Fig. 3: observed energy reduction versus SLA compliance
//! across the evaluated workloads (the paper's summary figure).

use crate::exp::common::{run_pair, ExpContext};
use crate::util::table::TableBuilder;
use crate::workload::{Mix, WorkloadKind};

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Fig. 3 — Energy reduction vs SLA compliance (series data)",
        &["workload", "energy reduction %", "sla compliance %"],
    );
    let mut series = Vec::new();
    for &k in &WorkloadKind::ALL {
        let pair = run_pair(ctx, &Mix::only(k), 5);
        series.push((k.name().to_string(), pair.savings(), pair.compliance()));
    }
    let pair = run_pair(ctx, &Mix::paper(), 5);
    series.push(("mixed".into(), pair.savings(), pair.compliance()));

    for (name, sav, comp) in &series {
        t.row(&[
            name.clone(),
            format!("{:.1}", sav * 100.0),
            format!("{:.1}", comp * 100.0),
        ]);
    }
    // Terminal rendering of the figure: bars for savings, compliance
    // annotated (all points should hug the 100 % line).
    println!("Fig. 3 (terminal render)");
    for (name, sav, comp) in &series {
        let bar = "█".repeat(((sav * 100.0).max(0.0) as usize).min(40));
        println!(
            "  {name:<12} {bar:<22} {:>5.1}%  | SLA {:>5.1}%",
            sav * 100.0,
            comp * 100.0
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_has_seven_points() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        assert_eq!(run(&ctx).n_rows(), 7);
    }
}
