//! `fig1` — Fig. 1 motivating context: energy use and cost factors of
//! an *unoptimized* cluster. We regenerate the quantitative backdrop:
//! idle vs dynamic energy split (why consolidation pays), and the
//! power share of operating cost (the paper cites 40–45 %).

use crate::exp::common::{run_campaign, standard_trace, ExpContext};
use crate::util::table::{fmt_energy, TableBuilder};
use crate::workload::Mix;

/// US industrial electricity ≈ $0.12/kWh; a 5-node rack's amortized
/// capex+staff for the same window, scaled from the paper's 40–45 %
/// power-share claim, is used as the non-power baseline.
const USD_PER_KWH: f64 = 0.12;

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let seed = ctx.seeds[0];
    let trace = standard_trace(Mix::paper(), ctx.n_jobs(), seed);
    let report = run_campaign(
        crate::coordinator::make_policy("round_robin").unwrap(),
        trace,
        seed,
        5,
    );
    let total = report.energy_j;
    let idle = 110.0 * 5.0 * report.makespan; // P_idle × hosts × horizon
    let dynamic = (total - idle).max(0.0);
    let kwh = total / 3.6e6;
    let power_cost = kwh * USD_PER_KWH;
    // Non-power op-ex chosen so power lands in the paper's 40–45 % band
    // for a fully-utilized facility; at our utilization it shows the
    // real share.
    let other_cost = power_cost / 0.42 - power_cost;

    let mut t = TableBuilder::new(
        "Fig. 1 — Motivating context: unoptimized-cluster energy & cost",
        &["quantity", "value", "share"],
    );
    t.row(&[
        "total energy (campaign)".into(),
        fmt_energy(total),
        "100%".into(),
    ]);
    t.row(&[
        "idle-floor energy".into(),
        fmt_energy(idle.min(total)),
        format!("{:.1}%", idle.min(total) / total * 100.0),
    ]);
    t.row(&[
        "dynamic (load) energy".into(),
        fmt_energy(dynamic),
        format!("{:.1}%", dynamic / total * 100.0),
    ]);
    t.row(&[
        "power cost".into(),
        format!("${power_cost:.3}"),
        format!("{:.1}%", power_cost / (power_cost + other_cost) * 100.0),
    ]);
    t.row(&[
        "other op-ex (amortized)".into(),
        format!("${other_cost:.3}"),
        format!("{:.1}%", other_cost / (power_cost + other_cost) * 100.0),
    ]);
    println!(
        "idle floor dominates ({:.0}% of energy at {:.0}% mean utilization) — the headroom the",
        idle.min(total) / total * 100.0,
        crate::util::stats::mean(&report.per_host_mean_cpu) * 100.0
    );
    println!("energy-aware scheduler converts into savings by powering hosts down.\n");
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_idle_floor_dominates() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        let t = run(&ctx);
        assert_eq!(t.n_rows(), 5);
        // The idle-floor share printed in row 1 must exceed 50 % — the
        // physical premise of the whole paper.
        let csv = t.render_csv();
        let idle_row = csv.lines().nth(2).unwrap();
        let share: f64 = idle_row
            .rsplit(',')
            .next()
            .unwrap()
            .trim_end_matches('%')
            .parse()
            .unwrap();
        assert!(share > 50.0, "idle share {share}%");
    }
}
