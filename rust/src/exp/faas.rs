//! `fig4` — serverless keep-alive ablation: cold-start rate × energy
//! across keep-alive policies on one Azure-shaped invocation trace.
//!
//! The comparison the figure makes: a fixed window (OpenWhisk-style)
//! either wastes warm memory on rare functions or misses the
//! inter-arrival of mid-frequency ones; the hybrid histogram sizes
//! each function's window from its observed inter-arrival quantile
//! and should reach a lower cold-start rate at equal-or-lower energy
//! (the acceptance bar the integration tests pin down).

use crate::coordinator::{CampaignConfig, Coordinator};
use crate::exp::common::ExpContext;
use crate::util::table::TableBuilder;
use crate::workload::faas::{FaasConfig, HybridParams, KeepAliveConfig};
use crate::workload::FaasTraceSpec;

/// Keep-alive variants the figure sweeps.
fn policies() -> Vec<(&'static str, KeepAliveConfig)> {
    vec![
        ("fixed_120s", KeepAliveConfig::Fixed { window: 120.0 }),
        ("fixed_30s", KeepAliveConfig::Fixed { window: 30.0 }),
        ("hybrid_hist", KeepAliveConfig::Hybrid(HybridParams::default())),
    ]
}

/// Trace sizing: small enough for smoke runs, big enough that the
/// histograms converge in full mode.
fn trace_spec(ctx: &ExpContext) -> FaasTraceSpec {
    if ctx.fast {
        FaasTraceSpec {
            n_functions: 30,
            n_invocations: 1200,
            ..Default::default()
        }
    } else {
        FaasTraceSpec::default()
    }
}

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Fig. 4 — keep-alive policy vs cold-start rate and energy",
        &[
            "keep-alive",
            "cold %",
            "cold starts",
            "warm starts",
            "boot J",
            "energy J/solo-s",
            "warm pool",
            "expired",
        ],
    );
    let spec = trace_spec(ctx);
    for (name, keep_alive) in policies() {
        let mut cold_rate = Vec::new();
        let mut cold = 0u64;
        let mut warm = 0u64;
        let mut boot_j = Vec::new();
        let mut jps = Vec::new();
        let mut pool = Vec::new();
        let mut expired = 0u64;
        for &seed in &ctx.seeds {
            let trace = spec.generate(seed);
            let mut coord = Coordinator::new(
                CampaignConfig::builder()
                    .hosts(8)
                    .seed(seed)
                    .faas(FaasConfig {
                        keep_alive,
                        ..Default::default()
                    })
                    .build()
                    .expect("valid campaign config"),
                crate::coordinator::make_policy("round_robin").unwrap(),
            );
            let r = coord.run(trace);
            cold_rate.push(r.cold_start_rate());
            cold += r.cold_starts;
            warm += r.warm_starts;
            boot_j.push(r.cold_start_energy_j);
            jps.push(r.j_per_solo_second());
            pool.push(r.warm_pool_mean);
            expired += r.containers_expired;
        }
        t.row(&[
            name.to_string(),
            format!("{:.1}", crate::util::stats::mean(&cold_rate) * 100.0),
            cold.to_string(),
            warm.to_string(),
            format!("{:.0}", crate::util::stats::mean(&boot_j)),
            format!("{:.1}", crate::util::stats::mean(&jps)),
            format!("{:.1}", crate::util::stats::mean(&pool)),
            expired.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_sweeps_every_keep_alive_policy() {
        let t = run(&ExpContext::fast());
        assert_eq!(t.n_rows(), 3);
        let csv = t.render_csv();
        assert!(csv.contains("fixed_120s"));
        assert!(csv.contains("hybrid_hist"));
    }
}
