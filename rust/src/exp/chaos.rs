//! `chaos` — fault-injection sweep: host-crash rate × checkpoint
//! interval × placement policy, plus a correlated rack-failure
//! scenario.
//!
//! The robustness question the table answers: as deterministic host
//! crashes ramp up (with telemetry blackouts and transient migration
//! failures riding along), how do energy-per-work, SLA compliance,
//! and recovery behave under the baseline vs the energy-aware policy
//! — and how much of the replayed work does checkpoint/restart buy
//! back? Evacuated jobs drain through the ordinary `decide_batch`
//! retry path with bounded backoff, so the sweep exercises the whole
//! fault pipeline end to end; the rack rows add correlated fail-stop
//! (a whole fault domain at one instant) and partial degradation on
//! top. Every campaign is replayable from `(seed, config)` alone.

use crate::coordinator::{CampaignConfig, Coordinator};
use crate::exp::common::{standard_trace, ExpContext};
use crate::sim::FaultConfig;
use crate::util::table::TableBuilder;
use crate::workload::Mix;

/// The chaos sweep's fault grid — the single source of truth for the
/// fault intensities exercised by this experiment *and* by the
/// `bench_chaos` micro-benchmark, so the benched campaigns stay
/// representative of the reported table.
#[derive(Debug, Clone)]
pub struct ChaosGrid {
    /// Independent host-crash rates swept (crashes per host-hour).
    /// Zero is the control row: fault machinery armed but silent.
    pub crash_rates: Vec<f64>,
    /// Checkpoint intervals swept at each non-zero crash rate
    /// (`None` = no checkpointing, the full-restart baseline).
    pub checkpoint_intervals: Vec<Option<f64>>,
    /// Correlated rack-crash rate for the rack scenario rows
    /// (crashes per rack-hour).
    pub rack_crash_rate_per_hour: f64,
    /// Partial-degradation rate for the rack scenario rows
    /// (episodes per host-hour: flaky disks and thermal caps).
    pub degrade_rate_per_hour: f64,
}

impl ChaosGrid {
    /// Smoke-sized grid (CI / `--fast`).
    pub fn fast() -> ChaosGrid {
        ChaosGrid {
            crash_rates: vec![0.0, 2.0],
            checkpoint_intervals: vec![None, Some(120.0)],
            rack_crash_rate_per_hour: 1.0,
            degrade_rate_per_hour: 1.0,
        }
    }

    /// Full sweep for the paper table.
    pub fn full() -> ChaosGrid {
        ChaosGrid {
            crash_rates: vec![0.0, 0.5, 2.0, 6.0],
            checkpoint_intervals: vec![None, Some(60.0), Some(300.0)],
            rack_crash_rate_per_hour: 1.0,
            degrade_rate_per_hour: 1.0,
        }
    }

    /// Fault config for one grid cell. Blackouts, migration failures,
    /// and a worker-panic probe scale on when crashes do — the zero
    /// row is a genuinely fault-free control. `rack` adds the
    /// correlated rack-crash and degradation streams on top.
    pub fn fault_config(&self, crash_rate: f64, rack: bool, checkpoint: Option<f64>) -> FaultConfig {
        FaultConfig {
            host_crash_rate_per_hour: crash_rate,
            blackout_rate_per_hour: if crash_rate > 0.0 { 0.2 } else { 0.0 },
            migration_failure_prob: if crash_rate > 0.0 { 0.05 } else { 0.0 },
            worker_panics: if crash_rate > 0.0 { 1 } else { 0 },
            rack_crash_rate_per_hour: if rack { self.rack_crash_rate_per_hour } else { 0.0 },
            degrade_rate_per_hour: if rack { self.degrade_rate_per_hour } else { 0.0 },
            checkpoint_interval_s: checkpoint,
            ..Default::default()
        }
    }

    /// The sweep's cells as `(crash_rate, rack_scenario, checkpoint)`.
    /// Checkpoint intervals are swept only where crashes can fire
    /// (the control row has nothing to restart); one rack row rides
    /// at the highest crash rate with the first configured interval.
    pub fn cells(&self) -> Vec<(f64, bool, Option<f64>)> {
        let mut cells = Vec::new();
        for &rate in &self.crash_rates {
            if rate == 0.0 {
                cells.push((rate, false, None));
            } else {
                for &ckpt in &self.checkpoint_intervals {
                    cells.push((rate, false, ckpt));
                }
            }
        }
        let top = self.crash_rates.iter().cloned().fold(0.0, f64::max);
        let ckpt = self.checkpoint_intervals.iter().flatten().next().copied();
        cells.push((top, true, ckpt));
        cells
    }
}

/// Explicit fault-domain map for the rack scenario: 8 hosts in 4
/// racks of 2 (the shard hash would also do, but pairs make the
/// cross-rack evacuation preference legible in the counters).
fn rack_map() -> Vec<usize> {
    vec![0, 0, 1, 1, 2, 2, 3, 3]
}

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let grid = if ctx.fast {
        ChaosGrid::fast()
    } else {
        ChaosGrid::full()
    };
    let mut t = TableBuilder::new(
        "Chaos — crash rate × checkpointing × policy: energy, SLA, and recovery",
        &[
            "policy",
            "crashes/h",
            "racks/h",
            "ckpt s",
            "energy J/solo-s",
            "SLA %",
            "crashes",
            "evacuations",
            "interrupted",
            "recovery s",
            "replace J",
            "ckpt J",
            "saved s",
        ],
    );
    for &(rate, rack, ckpt) in &grid.cells() {
        for policy_name in ["round_robin", "energy_aware"] {
            let mut jps = Vec::new();
            let mut sla = Vec::new();
            let mut crashes = 0u64;
            let mut evacuations = 0u64;
            let mut interrupted = 0usize;
            let mut recovery = Vec::new();
            let mut replace_j = Vec::new();
            let mut ckpt_j = Vec::new();
            let mut saved = Vec::new();
            for &seed in &ctx.seeds {
                let trace = standard_trace(Mix::paper(), ctx.n_jobs(), seed);
                let policy = match policy_name {
                    "round_robin" => crate::coordinator::make_policy("round_robin").unwrap(),
                    _ => ctx.energy_aware_policy(),
                };
                let mut builder = CampaignConfig::builder()
                    .hosts(8)
                    .seed(seed)
                    .faults(grid.fault_config(rate, rack, ckpt));
                if rack {
                    builder = builder.rack_map(rack_map());
                }
                let mut coord = Coordinator::new(
                    builder.build().expect("valid campaign config"),
                    policy,
                );
                let r = coord.run(trace);
                jps.push(r.j_per_solo_second());
                sla.push(r.sla_compliance);
                crashes += r.host_crashes;
                evacuations += r.evacuations;
                interrupted += r.interrupted_jobs;
                recovery.push(r.mean_recovery_latency_s);
                replace_j.push(r.replacement_energy_j);
                ckpt_j.push(r.checkpoint_energy_j);
                saved.push(r.progress_saved_s);
            }
            t.row(&[
                policy_name.to_string(),
                format!("{rate:.1}"),
                if rack {
                    format!("{:.1}", grid.rack_crash_rate_per_hour)
                } else {
                    "0.0".to_string()
                },
                ckpt.map_or_else(|| "-".to_string(), |i| format!("{i:.0}")),
                format!("{:.1}", crate::util::stats::mean(&jps)),
                format!("{:.1}", crate::util::stats::mean(&sla) * 100.0),
                crashes.to_string(),
                evacuations.to_string(),
                interrupted.to_string(),
                format!("{:.0}", crate::util::stats::mean(&recovery)),
                format!("{:.0}", crate::util::stats::mean(&replace_j)),
                format!("{:.0}", crate::util::stats::mean(&ckpt_j)),
                format!("{:.0}", crate::util::stats::mean(&saved)),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn chaos_sweeps_rate_by_checkpoint_by_policy() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = PathBuf::from("/nonexistent"); // force oracle
        let t = run(&ctx);
        // fast mode: control + (1 rate × 2 intervals) + rack row,
        // each × 2 policies.
        assert_eq!(t.n_rows(), 8);
        let csv = t.render_csv();
        assert!(csv.contains("round_robin"));
        assert!(csv.contains("energy_aware"));
    }

    #[test]
    fn grid_cells_cover_control_checkpoints_and_rack() {
        let g = ChaosGrid::fast();
        let cells = g.cells();
        assert!(cells.contains(&(0.0, false, None)));
        assert!(cells.contains(&(2.0, false, Some(120.0))));
        assert_eq!(cells.last(), Some(&(2.0, true, Some(120.0))));
        // The control cell is genuinely fault-free; faulted cells arm
        // the satellite fault classes too.
        let clean = g.fault_config(0.0, false, None);
        assert_eq!(clean.blackout_rate_per_hour, 0.0);
        assert_eq!(clean.worker_panics, 0);
        let rack = g.fault_config(2.0, true, Some(120.0));
        assert!(rack.rack_crash_rate_per_hour > 0.0);
        assert!(rack.degrade_rate_per_hour > 0.0);
        assert_eq!(rack.checkpoint_interval_s, Some(120.0));
    }
}
