//! `chaos` — fault-injection sweep: host-crash rate × placement policy.
//!
//! The robustness question the table answers: as deterministic host
//! crashes ramp up (with telemetry blackouts and transient migration
//! failures riding along), how do energy-per-work, SLA compliance,
//! and recovery behave under the baseline vs the energy-aware policy?
//! Evacuated jobs drain through the ordinary `decide_batch` retry
//! path with bounded backoff, so the sweep exercises the whole fault
//! pipeline end to end — and every campaign is replayable from
//! `(seed, config)` alone.

use crate::coordinator::{CampaignConfig, Coordinator};
use crate::exp::common::{standard_trace, ExpContext};
use crate::sim::FaultConfig;
use crate::util::table::TableBuilder;
use crate::workload::Mix;

/// Crash rates swept (crashes per host-hour). Zero is the control
/// row: the fault machinery armed but silent, pinning the no-fault
/// baseline in the same table.
fn crash_rates(ctx: &ExpContext) -> Vec<f64> {
    if ctx.fast {
        vec![0.0, 2.0]
    } else {
        vec![0.0, 0.5, 2.0, 6.0]
    }
}

fn fault_config(rate_per_hour: f64) -> FaultConfig {
    FaultConfig {
        host_crash_rate_per_hour: rate_per_hour,
        // Blackouts and migration failures scale on when crashes do —
        // the zero row is a genuinely fault-free control.
        blackout_rate_per_hour: if rate_per_hour > 0.0 { 0.2 } else { 0.0 },
        migration_failure_prob: if rate_per_hour > 0.0 { 0.05 } else { 0.0 },
        worker_panics: if rate_per_hour > 0.0 { 1 } else { 0 },
        ..Default::default()
    }
}

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Chaos — crash rate × policy: energy, SLA, and recovery",
        &[
            "policy",
            "crashes/h",
            "energy J/solo-s",
            "SLA %",
            "crashes",
            "evacuations",
            "interrupted",
            "recovery s",
            "replace J",
        ],
    );
    for &rate in &crash_rates(ctx) {
        for policy_name in ["round_robin", "energy_aware"] {
            let mut jps = Vec::new();
            let mut sla = Vec::new();
            let mut crashes = 0u64;
            let mut evacuations = 0u64;
            let mut interrupted = 0usize;
            let mut recovery = Vec::new();
            let mut replace_j = Vec::new();
            for &seed in &ctx.seeds {
                let trace = standard_trace(Mix::paper(), ctx.n_jobs(), seed);
                let policy = match policy_name {
                    "round_robin" => crate::coordinator::make_policy("round_robin").unwrap(),
                    _ => ctx.energy_aware_policy(),
                };
                let mut coord = Coordinator::new(
                    CampaignConfig::builder()
                        .hosts(8)
                        .seed(seed)
                        .faults(fault_config(rate))
                        .build()
                        .expect("valid campaign config"),
                    policy,
                );
                let r = coord.run(trace);
                jps.push(r.j_per_solo_second());
                sla.push(r.sla_compliance);
                crashes += r.host_crashes;
                evacuations += r.evacuations;
                interrupted += r.interrupted_jobs;
                recovery.push(r.mean_recovery_latency_s);
                replace_j.push(r.replacement_energy_j);
            }
            t.row(&[
                policy_name.to_string(),
                format!("{rate:.1}"),
                format!("{:.1}", crate::util::stats::mean(&jps)),
                format!("{:.1}", crate::util::stats::mean(&sla) * 100.0),
                crashes.to_string(),
                evacuations.to_string(),
                interrupted.to_string(),
                format!("{:.0}", crate::util::stats::mean(&recovery)),
                format!("{:.0}", crate::util::stats::mean(&replace_j)),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    #[test]
    fn chaos_sweeps_rate_by_policy() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = PathBuf::from("/nonexistent"); // force oracle
        let t = run(&ctx);
        // fast mode: 2 rates × 2 policies.
        assert_eq!(t.n_rows(), 4);
        let csv = t.render_csv();
        assert!(csv.contains("round_robin"));
        assert!(csv.contains("energy_aware"));
    }
}
