//! `fig2` — Fig. 2: the scheduling pipeline (profiling →
//! classification → prediction → placement) rendered as a measured
//! per-stage latency trace for one real decision.

use crate::cluster::Cluster;
use crate::exp::common::ExpContext;
use crate::profile::{build_features, classify, ResourceVector};
use crate::util::bench::fmt_time;
use crate::util::table::TableBuilder;
use crate::workload::{phases_for, WorkloadKind};
use std::time::Instant;

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Fig. 2 — Pipeline stages with measured latency (one decision)",
        &["stage", "output", "latency"],
    );
    let cluster = Cluster::homogeneous(5);
    let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(1);
    let phases = phases_for(WorkloadKind::HadoopTeraSort, 30.0, &mut rng);
    let flavor = crate::cluster::flavor::MEDIUM;

    // Stage 1: profiling (Eq. 1).
    let t0 = Instant::now();
    let vector = ResourceVector::from_phases(&phases, &flavor);
    let d_profile = t0.elapsed().as_secs_f64();
    t.row(&[
        "1. profile (Eq. 1)".into(),
        format!(
            "W = (c={:.2}, m={:.2}, d={:.2}, n={:.2})",
            vector.cpu, vector.mem, vector.disk, vector.net
        ),
        fmt_time(d_profile),
    ]);

    // Stage 2: classification (Eq. 2).
    let t0 = Instant::now();
    let class = classify(&vector);
    let d_class = t0.elapsed().as_secs_f64();
    t.row(&[
        "2. classify (Eq. 2)".into(),
        format!("T = {}", class.name()),
        fmt_time(d_class),
    ]);

    // Stage 3: prediction (Eq. 4) over all candidate hosts.
    let mut predictor = ctx.make_predictor();
    let feats: Vec<[f32; crate::profile::FEAT_DIM]> = cluster
        .hosts
        .iter()
        .map(|h| build_features(&vector, 900.0, h))
        .collect();
    let t0 = Instant::now();
    let preds = predictor.predict(&feats);
    let d_pred = t0.elapsed().as_secs_f64();
    t.row(&[
        format!("3. predict ({})", predictor.name()),
        format!(
            "Ê per host (W): {:?}",
            preds
                .iter()
                .map(|p| (p.power_w * 10.0).round() / 10.0)
                .collect::<Vec<_>>()
        ),
        fmt_time(d_pred),
    ]);

    // Stage 4: placement (Eqs. 6–7 argmin).
    let t0 = Instant::now();
    let best = preds
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.power_w.partial_cmp(&b.power_w).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let d_place = t0.elapsed().as_secs_f64();
    t.row(&[
        "4. place (Eqs. 6–7)".into(),
        format!("π(i) = host-{best}"),
        fmt_time(d_place),
    ]);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_traces_four_stages() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        let t = run(&ctx);
        assert_eq!(t.n_rows(), 4);
        assert!(t.render_csv().contains("io-bound")); // terasort classifies io
    }
}
