//! `table4` — §V-D baseline comparison: round-robin leaves hosts
//! uniformly underutilized; the energy-aware scheduler bimodalizes the
//! distribution (busy hosts + powered-down hosts).

use crate::exp::common::{print_spark, run_pair, ExpContext};
use crate::util::table::TableBuilder;
use crate::workload::Mix;

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let pair = run_pair(ctx, &Mix::paper(), 5);
    let base = &pair.baseline[0];
    let opt = &pair.optimized[0];

    let mut t = TableBuilder::new(
        "Table 4 — Host CPU-utilization distribution, RR vs energy-aware (§V-D)",
        &["cpu util bucket", "round-robin %", "energy-aware %"],
    );
    for i in 0..base.util_hist.buckets().len() {
        t.row(&[
            base.util_hist.label(i),
            format!("{:.1}", base.util_hist.frac(i) * 100.0),
            format!("{:.1}", opt.util_hist.frac(i) * 100.0),
        ]);
    }

    // Companion stats + timelines.
    let mean = |xs: &[f64]| crate::util::stats::mean(xs);
    println!(
        "active-host summary: RR mean hosts-on {:.2}, EA mean hosts-on {:.2}",
        base.hosts_on_trace.time_mean(0.0, base.makespan),
        opt.hosts_on_trace.time_mean(0.0, opt.makespan),
    );
    println!(
        "powered-down host-hours: RR {:.2}, EA {:.2}  | power cycles: RR {}, EA {}",
        base.host_off_s / 3600.0,
        opt.host_off_s / 3600.0,
        base.power_cycles,
        opt.power_cycles,
    );
    println!(
        "per-host mean cpu: RR {:?} (max-min {:.3}), EA {:?}",
        base.per_host_mean_cpu
            .iter()
            .map(|x| (x * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
        spread(&base.per_host_mean_cpu),
        opt.per_host_mean_cpu
            .iter()
            .map(|x| (x * 100.0).round() / 100.0)
            .collect::<Vec<_>>(),
    );
    let _ = mean;
    let rr_series: Vec<f64> = base
        .hosts_on_trace
        .resample(0.0, base.makespan, 60)
        .iter()
        .map(|(_, v)| *v)
        .collect();
    let ea_series: Vec<f64> = opt
        .hosts_on_trace
        .resample(0.0, opt.makespan, 60)
        .iter()
        .map(|(_, v)| *v)
        .collect();
    print_spark("hosts-on (RR)", &rr_series);
    print_spark("hosts-on (energy-aware)", &ea_series);
    t
}

fn spread(xs: &[f64]) -> f64 {
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    max - min
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table4_has_ten_buckets() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        assert_eq!(run(&ctx).n_rows(), 10);
    }
}
