//! `table1` — §V-A energy savings: per-benchmark campaigns under the
//! round-robin baseline vs the energy-aware scheduler. The paper
//! reports 15–20 % savings overall with TeraSort ≈ 19 %.

use crate::exp::common::{run_pair, ExpContext};
use crate::util::table::{fmt_energy, fmt_pm, TableBuilder};
use crate::workload::{Mix, WorkloadKind};

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Table 1 — Energy consumption: baseline vs energy-aware (§V-A)",
        &[
            "workload",
            "baseline J/solo-s",
            "optimized J/solo-s",
            "savings",
            "baseline total",
            "optimized total",
        ],
    );
    let mut rows: Vec<(String, Mix)> = WorkloadKind::ALL
        .iter()
        .map(|&k| (k.name().to_string(), Mix::only(k)))
        .collect();
    rows.push(("mixed (paper)".into(), Mix::paper()));

    for (name, mix) in rows {
        let pair = run_pair(ctx, &mix, 5);
        let base_jps: Vec<f64> = pair.baseline.iter().map(|r| r.j_per_solo_second()).collect();
        let opt_jps: Vec<f64> = pair.optimized.iter().map(|r| r.j_per_solo_second()).collect();
        let base_total: f64 = crate::util::stats::mean(
            &pair.baseline.iter().map(|r| r.energy_j).collect::<Vec<_>>(),
        );
        let opt_total: f64 = crate::util::stats::mean(
            &pair.optimized.iter().map(|r| r.energy_j).collect::<Vec<_>>(),
        );
        t.row(&[
            name,
            fmt_pm(
                crate::util::stats::mean(&base_jps),
                crate::util::stats::std_dev(&base_jps),
                1,
            ),
            fmt_pm(
                crate::util::stats::mean(&opt_jps),
                crate::util::stats::std_dev(&opt_jps),
                1,
            ),
            format!(
                "{:.1}% ± {:.1}",
                pair.savings() * 100.0,
                pair.savings_std() * 100.0
            ),
            fmt_energy(base_total),
            fmt_energy(opt_total),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_workloads_and_positive_savings() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent"); // oracle
        let t = run(&ctx);
        assert_eq!(t.n_rows(), 7);
        let csv = t.render_csv();
        assert!(csv.contains("terasort"));
        assert!(csv.contains("mixed (paper)"));
    }
}
