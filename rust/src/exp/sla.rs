//! `table2` — §V-B SLA compliance and performance: JCT deviation vs
//! the baseline must stay under 5 %, compliance at 100 %.

use crate::exp::common::{run_pair, ExpContext};
use crate::util::table::{fmt_pct, TableBuilder};
use crate::workload::{Mix, WorkloadKind};

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Table 2 — SLA compliance and JCT deviation (§V-B)",
        &[
            "workload",
            "jct deviation",
            "sla compliance",
            "violations",
            "mean slowdown vs solo",
        ],
    );
    let mut rows: Vec<(String, Mix)> = WorkloadKind::ALL
        .iter()
        .map(|&k| (k.name().to_string(), Mix::only(k)))
        .collect();
    rows.push(("mixed (paper)".into(), Mix::paper()));

    for (name, mix) in rows {
        let pair = run_pair(ctx, &mix, 5);
        let violations: usize = pair.optimized.iter().map(|r| r.sla_violations).sum();
        let slow = crate::util::stats::mean(
            &pair
                .optimized
                .iter()
                .map(|r| r.mean_slowdown)
                .collect::<Vec<_>>(),
        );
        t.row(&[
            name,
            format!("{:+.1}%", pair.jct_deviation() * 100.0),
            fmt_pct(pair.compliance()),
            violations.to_string(),
            format!("{:+.1}%", slow * 100.0),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_reports_compliance() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        let t = run(&ctx);
        assert_eq!(t.n_rows(), 7);
        // Fast-mode invariant: the mixed row must show 100 % compliance.
        assert!(t.render_csv().lines().last().unwrap().contains("100.0%"));
    }
}
