//! Ablations:
//! * `abl1` — δ_low × δ_high threshold sweep (§VI-B's tunability):
//!   the savings/SLA trade-off frontier.
//! * `abl2` — predictor choice (§III-B): oracle vs MLP vs decision
//!   tree vs linear vs no-predictor baselines.
//! * `abl3` — DVFS on/off for I/O-heavy tenants (§III-C).

use crate::coordinator::{CampaignConfig, Coordinator};
use crate::exp::common::{run_campaign, standard_trace, ExpContext};
use crate::predict::{
    synthesize, DecisionTree, LinearModel, LinearPredictor, OraclePredictor, TreeParams,
    TreePredictor,
};
use crate::sched::{ConsolidationParams, EnergyAware, EnergyAwareParams};
use crate::util::table::TableBuilder;
use crate::workload::Mix;

pub fn run_abl1(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Ablation 1 — consolidation thresholds δ_low × δ_high (Eqs. 8–9)",
        &["δ_low", "δ_high", "savings %", "compliance %", "migrations"],
    );
    let lows = if ctx.fast { vec![0.2] } else { vec![0.1, 0.2, 0.3] };
    let highs = if ctx.fast {
        vec![0.85]
    } else {
        vec![0.75, 0.85, 0.95]
    };
    for &dl in &lows {
        for &dh in &highs {
            let mut savings = Vec::new();
            let mut comp = Vec::new();
            let mut migr = 0u64;
            for &seed in &ctx.seeds {
                let trace = standard_trace(Mix::paper(), ctx.n_jobs(), seed);
                let base = run_campaign(
                    crate::coordinator::make_policy("round_robin").unwrap(),
                    trace.clone(),
                    seed,
                    5,
                );
                let mut coord = Coordinator::new(
                    CampaignConfig::builder()
                        .seed(seed)
                        .consolidation(Some(ConsolidationParams {
                            delta_low: dl,
                            delta_high: dh,
                            ..Default::default()
                        }))
                        .build()
                        .expect("valid campaign config"),
                    Box::new(EnergyAware::new(
                        ctx.make_predictor(),
                        EnergyAwareParams {
                            delta_high: dh,
                            ..Default::default()
                        },
                    )),
                );
                let opt = coord.run(trace);
                savings.push(1.0 - opt.j_per_solo_second() / base.j_per_solo_second());
                comp.push(opt.sla_compliance);
                migr += opt.migrations;
            }
            t.row(&[
                format!("{dl:.2}"),
                format!("{dh:.2}"),
                format!("{:.1}", crate::util::stats::mean(&savings) * 100.0),
                format!("{:.1}", crate::util::stats::mean(&comp) * 100.0),
                migr.to_string(),
            ]);
        }
    }
    t
}

pub fn run_abl2(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Ablation 2 — prediction engine choice (§III-B)",
        &[
            "predictor",
            "savings %",
            "compliance %",
            "decision µs",
            "val MSE",
        ],
    );
    // Fit the learned baselines on the same oracle-labeled data.
    let ds = synthesize(4000, 7, None);
    let (train, val) = ds.split(0.9);
    let tree = DecisionTree::fit(&train.xs, &train.ys, TreeParams::default());
    let tree_mse = val.mse(|x| tree.eval(x));
    let lin = LinearModel::fit(&train.xs, &train.ys, 1e-4);
    let lin_mse = val.mse(|x| lin.eval(x));
    let mlp_mse = if ctx.has_artifacts() {
        match ctx.ensure_weights() {
            Some(w) => {
                let mut m = crate::predict::NativeMlp::new(w);
                val.mse(|x| {
                    let (a, b) = m.forward(x);
                    [a, b]
                })
            }
            None => f64::NAN,
        }
    } else {
        f64::NAN
    };

    type MakePred = Box<dyn Fn() -> Box<dyn crate::predict::EnergyPredictor>>;
    let mut rows: Vec<(&str, f64, MakePred)> = vec![
        ("oracle", 0.0, Box::new(|| Box::new(OraclePredictor))),
        (
            "dtree",
            tree_mse,
            Box::new(move || Box::new(TreePredictor::new(tree.clone()))),
        ),
        (
            "linear",
            lin_mse,
            Box::new(move || Box::new(LinearPredictor::new(lin.clone()))),
        ),
    ];
    if ctx.has_artifacts() {
        let ctx2 = ctx.clone();
        rows.insert(
            1,
            (
                "mlp (xla)",
                mlp_mse,
                Box::new(move || ctx2.make_predictor()),
            ),
        );
    }

    for (name, mse, make) in rows {
        let mut savings = Vec::new();
        let mut comp = Vec::new();
        let mut decision_us = Vec::new();
        for &seed in &ctx.seeds {
            let trace = standard_trace(Mix::paper(), ctx.n_jobs(), seed);
            let base = run_campaign(
                crate::coordinator::make_policy("round_robin").unwrap(),
                trace.clone(),
                seed,
                5,
            );
            let opt = run_campaign(
                Box::new(EnergyAware::new(make(), EnergyAwareParams::default())),
                trace,
                seed,
                5,
            );
            savings.push(1.0 - opt.j_per_solo_second() / base.j_per_solo_second());
            comp.push(opt.sla_compliance);
            decision_us.push(opt.overhead.per_decision_us());
        }
        t.row(&[
            name.to_string(),
            format!("{:.1}", crate::util::stats::mean(&savings) * 100.0),
            format!("{:.1}", crate::util::stats::mean(&comp) * 100.0),
            format!("{:.1}", crate::util::stats::mean(&decision_us)),
            if mse.is_nan() {
                "n/a".into()
            } else {
                format!("{mse:.5}")
            },
        ]);
    }
    t
}

pub fn run_abl3(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Ablation 3 — DVFS for I/O-bound workloads (§III-C)",
        &["mix", "dvfs", "energy J/solo-s", "savings vs RR %", "mean slowdown %"],
    );
    for (mix_name, mix) in [("io_heavy", Mix::io_heavy()), ("cpu_heavy", Mix::cpu_heavy())] {
        for dvfs_on in [true, false] {
            let mut jps = Vec::new();
            let mut savings = Vec::new();
            let mut slow = Vec::new();
            for &seed in &ctx.seeds {
                let trace = standard_trace(mix.clone(), ctx.n_jobs(), seed);
                let base = run_campaign(
                    crate::coordinator::make_policy("round_robin").unwrap(),
                    trace.clone(),
                    seed,
                    5,
                );
                let mut coord = Coordinator::new(
                    CampaignConfig::builder()
                        .seed(seed)
                        .dvfs(if dvfs_on { Some(Default::default()) } else { None })
                        .build()
                        .expect("valid campaign config"),
                    Box::new(EnergyAware::new(
                        ctx.make_predictor(),
                        EnergyAwareParams::default(),
                    )),
                );
                let opt = coord.run(trace);
                jps.push(opt.j_per_solo_second());
                savings.push(1.0 - opt.j_per_solo_second() / base.j_per_solo_second());
                slow.push(opt.mean_slowdown);
            }
            t.row(&[
                mix_name.to_string(),
                if dvfs_on { "on" } else { "off" }.to_string(),
                format!("{:.1}", crate::util::stats::mean(&jps)),
                format!("{:.1}", crate::util::stats::mean(&savings) * 100.0),
                format!("{:+.1}", crate::util::stats::mean(&slow) * 100.0),
            ]);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ExpContext {
        let mut c = ExpContext::fast();
        c.artifacts = std::path::PathBuf::from("/nonexistent");
        c
    }

    #[test]
    fn abl1_fast_has_one_cell() {
        assert_eq!(run_abl1(&ctx()).n_rows(), 1);
    }

    #[test]
    fn abl2_includes_learned_predictors() {
        let t = run_abl2(&ctx());
        let csv = t.render_csv();
        assert!(csv.contains("oracle"));
        assert!(csv.contains("dtree"));
        assert!(csv.contains("linear"));
    }

    #[test]
    fn abl3_has_four_rows() {
        assert_eq!(run_abl3(&ctx()).n_rows(), 4);
    }
}
