//! `table3` — §V-C workload-specific observations: consolidation
//! behaviour by dominant-resource class. CPU-bound jobs show limited
//! consolidation; I/O-bound Hadoop co-locates densely; ETL saves most
//! when scheduled into low-load periods.

use crate::cluster::flavor::MEDIUM;
use crate::exp::common::{run_campaign, standard_trace, ExpContext};
use crate::profile::{classify, ResourceVector, WorkloadClass};
use crate::util::table::TableBuilder;
use crate::workload::{phases_for, Mix, WorkloadKind};

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Table 3 — Per-class behaviour under the energy-aware scheduler (§V-C)",
        &[
            "workload",
            "class (Eq.2)",
            "mean slowdown",
            "migrations/job",
            "energy/job",
            "savings vs RR",
        ],
    );
    for &kind in &WorkloadKind::ALL {
        // Classify from the phase model (the profiler's cold path).
        let mut rng = crate::util::rng::Xoshiro256::seed_from_u64(17);
        let phases = phases_for(kind, 20.0, &mut rng);
        let class = classify(&ResourceVector::from_phases(&phases, &MEDIUM));

        let mut slows = Vec::new();
        let mut migr = Vec::new();
        let mut energy = Vec::new();
        let mut savings = Vec::new();
        for &seed in &ctx.seeds {
            let trace = standard_trace(Mix::only(kind), ctx.n_jobs(), seed);
            let base = run_campaign(
                crate::coordinator::make_policy("round_robin").unwrap(),
                trace.clone(),
                seed,
                5,
            );
            let opt = run_campaign(ctx.energy_aware_policy(), trace, seed, 5);
            slows.push(opt.mean_slowdown);
            migr.push(opt.migrations as f64 / opt.jobs.len().max(1) as f64);
            energy.push(
                opt.jobs.iter().map(|j| j.energy_j).sum::<f64>() / opt.jobs.len().max(1) as f64,
            );
            savings.push(1.0 - opt.j_per_solo_second() / base.j_per_solo_second());
        }
        t.row(&[
            kind.name().to_string(),
            class.name().to_string(),
            format!("{:+.1}%", crate::util::stats::mean(&slows) * 100.0),
            format!("{:.2}", crate::util::stats::mean(&migr)),
            crate::util::table::fmt_energy(crate::util::stats::mean(&energy)),
            format!("{:.1}%", crate::util::stats::mean(&savings) * 100.0),
        ]);
    }
    t
}

/// The §V-C qualitative claims as a checkable summary, printed after
/// the table (and asserted shape-level in rust/tests/experiments.rs).
pub fn class_expectations() -> Vec<(WorkloadKind, WorkloadClass)> {
    vec![
        (WorkloadKind::SparkLogReg, WorkloadClass::CpuBound),
        (WorkloadKind::SparkKMeans, WorkloadClass::CpuBound),
        (WorkloadKind::HadoopTeraSort, WorkloadClass::IoBound),
        (WorkloadKind::HadoopGrep, WorkloadClass::IoBound),
        (WorkloadKind::EtlPipeline, WorkloadClass::IoBound),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_covers_all_kinds() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        let t = run(&ctx);
        assert_eq!(t.n_rows(), WorkloadKind::ALL.len());
        let csv = t.render_csv();
        assert!(csv.contains("cpu-bound"));
        assert!(csv.contains("io-bound"));
    }
}
