//! Shared experiment infrastructure: standard campaigns, predictor
//! setup (train-if-needed), seed averaging, and result output.

use crate::coordinator::{CampaignConfig, CampaignReport, Coordinator};
use crate::predict::{
    synthesize, EnergyPredictor, MlpWeights, NativeMlp, OraclePredictor, Trainer, XlaMlp,
};
use crate::runtime::Runtime;
use crate::sched::{EnergyAware, EnergyAwareParams, PlacementPolicy};
use crate::util::table::TableBuilder;
use crate::workload::{Arrivals, Job, Mix, TraceSpec};
use std::path::{Path, PathBuf};

/// Experiment context from the CLI.
#[derive(Debug, Clone)]
pub struct ExpContext {
    pub seeds: Vec<u64>,
    pub out_dir: PathBuf,
    pub artifacts: PathBuf,
    /// Smaller campaigns for smoke runs / CI.
    pub fast: bool,
}

impl Default for ExpContext {
    fn default() -> Self {
        ExpContext {
            seeds: vec![1, 2, 3],
            out_dir: PathBuf::from("results"),
            artifacts: PathBuf::from("artifacts"),
            fast: false,
        }
    }
}

impl ExpContext {
    pub fn fast() -> ExpContext {
        ExpContext {
            seeds: vec![1],
            fast: true,
            ..Default::default()
        }
    }

    /// Jobs per campaign.
    pub fn n_jobs(&self) -> usize {
        if self.fast {
            10
        } else {
            24
        }
    }

    /// Whether the PJRT artifacts are available.
    pub fn has_artifacts(&self) -> bool {
        self.artifacts.join("meta.json").exists()
    }

    /// The production predictor: the trained MLP through the XLA/PJRT
    /// path. Trains + persists weights on first use; falls back to the
    /// analytic oracle when artifacts are absent (with a warning), so
    /// experiments remain runnable on a fresh checkout. The same
    /// instance serves placement (`decide_batch`) and the control
    /// loops (via the policy's scoring handle).
    pub fn make_predictor(&self) -> Box<dyn EnergyPredictor> {
        if !self.has_artifacts() {
            log::warn!("artifacts missing; experiments use the oracle predictor");
            return Box::new(OraclePredictor);
        }
        let Some(weights) = self.ensure_weights() else {
            // Artifacts exist but no trained weights and no runtime
            // to train with: untrained-MLP scores would be noise, so
            // keep the analytic oracle.
            log::warn!("no trained weights and no XLA runtime; using the oracle predictor");
            return Box::new(OraclePredictor);
        };
        match Runtime::new(&self.artifacts).and_then(|rt| XlaMlp::new(rt, weights.clone())) {
            Ok(xla) => Box::new(xla),
            Err(e) => {
                log::warn!("XLA runtime unavailable ({e}); using native MLP");
                Box::new(NativeMlp::new(weights))
            }
        }
    }

    /// Trained weights, training once and caching to
    /// `artifacts/weights.json`. `None` when no cached weights exist
    /// and the XLA runtime (which owns `train_step.hlo`) is
    /// unavailable — callers must not score with untrained weights.
    pub fn ensure_weights(&self) -> Option<MlpWeights> {
        let path = self.artifacts.join("weights.json");
        if let Some(w) = MlpWeights::load(&path) {
            return Some(w);
        }
        log::info!("training f_θ (first run) …");
        let rt = match Runtime::new(&self.artifacts) {
            Ok(rt) => rt,
            Err(e) => {
                log::warn!("XLA runtime unavailable ({e}); cannot train f_θ");
                return None;
            }
        };
        let ds = synthesize(4096, 7, None);
        let (train, val) = ds.split(0.9);
        let mut trainer = Trainer::new(rt, MlpWeights::init(42)).expect("trainer");
        let report = trainer.train(&train, &val, 30, 1).expect("training");
        log::info!(
            "trained: loss {:.5} → {:.5}, val mse {:.6}",
            report.loss_curve.first().unwrap(),
            report.loss_curve.last().unwrap(),
            report.val_mse
        );
        trainer.weights.save(&path).expect("persist weights");
        Some(trainer.weights)
    }

    /// The paper's energy-aware policy with the production predictor.
    pub fn energy_aware_policy(&self) -> Box<dyn PlacementPolicy> {
        Box::new(EnergyAware::new(
            self.make_predictor(),
            EnergyAwareParams::default(),
        ))
    }

    pub fn write_table(&self, name: &str, table: &TableBuilder) {
        let path = self.out_dir.join(format!("{name}.csv"));
        if let Err(e) = table.write_csv(&path) {
            log::warn!("failed to write {}: {e}", path.display());
        }
        println!("{}", table.render());
        println!("→ {}\n", path.display());
    }
}

/// The standard campaign trace: Poisson arrivals at *moderate* load —
/// "savings were most pronounced during periods of moderate or mixed
/// utilization" (§V-A). The arrival gap is self-calibrated per mix so
/// every campaign (short grep scans vs hour-long TeraSorts) sits at
/// the same operating point: offered load ≈ 35 % of the fleet in the
/// mix's dominant resource — the regime where the paper reports the
/// 15–20 % headline.
pub fn standard_trace(mix: Mix, n_jobs: usize, seed: u64) -> Vec<Job> {
    standard_trace_scaled(mix, n_jobs, seed, 5)
}

/// [`standard_trace`] for an `n_hosts`-sized fleet: the same ~35 %
/// dominant-resource operating point, offered load scaled with the
/// cluster (used by the `scale` experiment).
pub fn standard_trace_scaled(mix: Mix, n_jobs: usize, seed: u64, n_hosts: usize) -> Vec<Job> {
    // Estimate the mix's mean solo duration on a calibration sample.
    let probe = TraceSpec {
        mix: mix.clone(),
        n_jobs: 64,
        arrivals: Arrivals::Batch,
        horizon: 7200.0,
    }
    .generate(0xCA11B);
    let mean_solo =
        probe.iter().map(|j| j.solo_duration()).sum::<f64>() / probe.len() as f64;
    // Dominant-resource load one worker VM of this mix puts on a host
    // (e.g. a grep scan saturates ~34 % of a host's disk, a Spark
    // iteration ~19 % of its CPU). Target: the offered load occupies
    // ~35 % of the 5-host fleet in its dominant dimension — the
    // "moderate utilization" operating point of §V-A (the paper ran
    // finite benchmark batches, not a saturated stream).
    let flavor = crate::cluster::flavor::MEDIUM;
    // The admission-binding footprint of one VM includes the *flavor
    // reservation* floors (memory is never oversubscribed: a MEDIUM
    // worker pins 1/4 host regardless of its mean demand).
    let mem_floor = flavor.mem_gb / 64.0;
    let cpu_floor = flavor.vcpus / (32.0 * 1.5);
    let mean_dom = probe
        .iter()
        .map(|j| {
            let v = crate::profile::ResourceVector::from_phases(&j.phases, &flavor);
            (v.cpu * crate::predict::oracle::RATIO_CPU)
                .max(v.mem * crate::predict::oracle::RATIO_MEM)
                .max(v.disk * crate::predict::oracle::RATIO_DISK)
                .max(v.net * crate::predict::oracle::RATIO_NET)
                .max(mem_floor)
                .max(cpu_floor)
        })
        .sum::<f64>()
        / probe.len() as f64;
    let target_concurrency =
        (0.35 * n_hosts as f64 / mean_dom.max(0.05)).clamp(4.0, 12.0 * n_hosts as f64 / 5.0);
    let mean_gap = (mean_solo / target_concurrency).clamp(10.0, 120.0);
    // Campaigns must be long relative to the consolidation response
    // time (scan 30 s + grace 60 s + boot 90 s), or power management
    // can never catch up with short-job churn: stretch the job count
    // so arrivals span ≥ ~40 simulated minutes. (Full mode only —
    // fast/smoke campaigns keep their small job count.)
    let n_jobs = if n_jobs >= 20 {
        n_jobs.max((2400.0 / mean_gap) as usize)
    } else {
        n_jobs
    };
    TraceSpec {
        mix,
        n_jobs,
        arrivals: Arrivals::Poisson { mean_gap },
        horizon: 7200.0,
    }
    .generate(seed)
}

/// Run one campaign with the given policy.
pub fn run_campaign(
    policy: Box<dyn PlacementPolicy>,
    trace: Vec<Job>,
    seed: u64,
    n_hosts: usize,
) -> CampaignReport {
    let mut coord = Coordinator::new(
        CampaignConfig::builder()
            .hosts(n_hosts)
            .seed(seed)
            .build()
            .expect("valid campaign config"),
        policy,
    );
    coord.run(trace)
}

/// Baseline vs energy-aware pair on identical traces (the §IV-E
/// methodology), averaged over the context's seeds.
pub struct Pair {
    pub baseline: Vec<CampaignReport>,
    pub optimized: Vec<CampaignReport>,
}

pub fn run_pair(ctx: &ExpContext, mix: &Mix, n_hosts: usize) -> Pair {
    let mut baseline = Vec::new();
    let mut optimized = Vec::new();
    for &seed in &ctx.seeds {
        let trace = standard_trace(mix.clone(), ctx.n_jobs(), seed);
        baseline.push(run_campaign(
            crate::coordinator::make_policy("round_robin").unwrap(),
            trace.clone(),
            seed,
            n_hosts,
        ));
        optimized.push(run_campaign(
            ctx.energy_aware_policy(),
            trace,
            seed,
            n_hosts,
        ));
    }
    Pair {
        baseline,
        optimized,
    }
}

impl Pair {
    /// Energy-per-work savings fraction (mean over seeds), the
    /// §V-A headline number.
    pub fn savings(&self) -> f64 {
        let per_seed: Vec<f64> = self
            .baseline
            .iter()
            .zip(&self.optimized)
            .map(|(b, o)| 1.0 - o.j_per_solo_second() / b.j_per_solo_second())
            .collect();
        crate::util::stats::mean(&per_seed)
    }

    pub fn savings_std(&self) -> f64 {
        let per_seed: Vec<f64> = self
            .baseline
            .iter()
            .zip(&self.optimized)
            .map(|(b, o)| 1.0 - o.j_per_solo_second() / b.j_per_solo_second())
            .collect();
        crate::util::stats::std_dev(&per_seed)
    }

    /// Mean JCT deviation of optimized vs baseline (§V-B): mean over
    /// seeds of (mean JCT opt / mean JCT base − 1).
    pub fn jct_deviation(&self) -> f64 {
        let per_seed: Vec<f64> = self
            .baseline
            .iter()
            .zip(&self.optimized)
            .map(|(b, o)| {
                let mb = crate::util::stats::mean(
                    &b.jobs.iter().map(|j| j.jct).collect::<Vec<_>>(),
                );
                let mo = crate::util::stats::mean(
                    &o.jobs.iter().map(|j| j.jct).collect::<Vec<_>>(),
                );
                mo / mb - 1.0
            })
            .collect();
        crate::util::stats::mean(&per_seed)
    }

    pub fn compliance(&self) -> f64 {
        crate::util::stats::mean(
            &self
                .optimized
                .iter()
                .map(|o| o.sla_compliance)
                .collect::<Vec<_>>(),
        )
    }
}

/// Ensure the weights exist when artifacts do (used by `ecosched train`
/// and the experiment preamble).
pub fn maybe_train(ctx: &ExpContext) {
    if ctx.has_artifacts() {
        let _ = ctx.ensure_weights();
    }
}

/// Helper: artifacts dir resolution for tests and binaries that may
/// run from the workspace root or from `target/`.
pub fn find_artifacts() -> PathBuf {
    for cand in [
        PathBuf::from("artifacts"),
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    ] {
        if cand.join("meta.json").exists() {
            return cand;
        }
    }
    PathBuf::from("artifacts")
}

/// Quick textual figure: a labeled sparkline.
pub fn print_spark(label: &str, values: &[f64]) {
    println!("{label:<28} {}", crate::util::timeline::sparkline(values));
}

#[allow(dead_code)]
fn _assert_path_usable(_p: &Path) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_context_is_small() {
        let ctx = ExpContext::fast();
        assert_eq!(ctx.seeds.len(), 1);
        assert!(ctx.n_jobs() < 15);
    }

    #[test]
    fn pair_with_oracle_produces_savings() {
        // Oracle predictor (no artifacts needed): the pair helper must
        // show the headline effect even in fast mode.
        let mut ctx = ExpContext::fast();
        ctx.artifacts = PathBuf::from("/nonexistent"); // force oracle
        let pair = run_pair(&ctx, &Mix::paper(), 5);
        assert_eq!(pair.baseline.len(), 1);
        let s = pair.savings();
        assert!(s > 0.03, "savings {s}");
        assert!(pair.compliance() >= 0.99);
    }
}
