//! `scale` — §VI-C limitations probe: the paper evaluates on five
//! nodes and flags larger deployments as open; we sweep cluster size
//! to show savings stability and coordinator-overhead growth.

use crate::exp::common::{run_campaign, standard_trace_scaled, ExpContext};
use crate::util::table::TableBuilder;
use crate::workload::Mix;

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let mut t = TableBuilder::new(
        "Scale-out — savings and overhead vs cluster size (§VI-C)",
        &[
            "hosts",
            "jobs",
            "savings %",
            "compliance %",
            "decision µs",
            "scan wall s",
        ],
    );
    let sizes: Vec<usize> = if ctx.fast {
        vec![5, 10]
    } else {
        vec![5, 10, 20, 40, 80]
    };
    for &n_hosts in &sizes {
        // Offered load scales with the cluster at the same calibrated
        // moderate operating point as every other experiment.
        let n_jobs = ctx.n_jobs() * n_hosts / 5;
        let mut savings = Vec::new();
        let mut comp = Vec::new();
        let mut dus = Vec::new();
        let mut scan = Vec::new();
        for &seed in &ctx.seeds {
            let trace = standard_trace_scaled(Mix::paper(), n_jobs, seed, n_hosts);
            let base = run_campaign(
                crate::coordinator::make_policy("round_robin").unwrap(),
                trace.clone(),
                seed,
                n_hosts,
            );
            let opt = run_campaign(ctx.energy_aware_policy(), trace, seed, n_hosts);
            savings.push(1.0 - opt.j_per_solo_second() / base.j_per_solo_second());
            comp.push(opt.sla_compliance);
            dus.push(opt.overhead.per_decision_us());
            scan.push(opt.overhead.scan_wall_s);
        }
        t.row(&[
            n_hosts.to_string(),
            n_jobs.to_string(),
            format!("{:.1}", crate::util::stats::mean(&savings) * 100.0),
            format!("{:.1}", crate::util::stats::mean(&comp) * 100.0),
            format!("{:.1}", crate::util::stats::mean(&dus)),
            format!("{:.4}", crate::util::stats::mean(&scan)),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_fast_two_sizes() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        assert_eq!(run(&ctx).n_rows(), 2);
    }
}
