//! `table5` — §V-E system overhead: the profiling + prediction path
//! must cost <5 % CPU; migration overhead must be absorbed in
//! low-activity windows with no SLA effect.

use crate::exp::common::{run_pair, ExpContext};
use crate::util::table::TableBuilder;
use crate::workload::Mix;

pub fn run(ctx: &ExpContext) -> TableBuilder {
    let pair = run_pair(ctx, &Mix::paper(), 5);
    let mut t = TableBuilder::new(
        "Table 5 — Scheduler overhead (§V-E)",
        &["metric", "round-robin", "energy-aware"],
    );
    let b = &pair.baseline[0];
    let o = &pair.optimized[0];
    let rows: Vec<(&str, String, String)> = vec![
        (
            "placement decisions",
            b.overhead.n_decisions.to_string(),
            o.overhead.n_decisions.to_string(),
        ),
        (
            "decision latency (µs, mean)",
            format!("{:.1}", b.overhead.per_decision_us()),
            format!("{:.1}", o.overhead.per_decision_us()),
        ),
        (
            "controller CPU share (%)",
            format!("{:.4}", b.overhead.cpu_share(b.makespan) * 100.0),
            format!("{:.4}", o.overhead.cpu_share(o.makespan) * 100.0),
        ),
        (
            "consolidation scan wall (s)",
            format!("{:.4}", b.overhead.scan_wall_s),
            format!("{:.4}", o.overhead.scan_wall_s),
        ),
        (
            "migrations",
            b.migrations.to_string(),
            o.migrations.to_string(),
        ),
        (
            "migration stall total (s)",
            format!("{:.1}", b.migration_stall_s),
            format!("{:.1}", o.migration_stall_s),
        ),
        (
            "stall share of total JCT (%)",
            "0.00".into(),
            format!(
                "{:.2}",
                o.migration_stall_s / o.jobs.iter().map(|j| j.jct).sum::<f64>() * 100.0
            ),
        ),
        (
            "SLA violations",
            b.sla_violations.to_string(),
            o.sla_violations.to_string(),
        ),
    ];
    for (name, bv, ov) in rows {
        t.row(&[name.to_string(), bv, ov]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_under_five_percent() {
        let mut ctx = ExpContext::fast();
        ctx.artifacts = std::path::PathBuf::from("/nonexistent");
        let pair = run_pair(&ctx, &Mix::paper(), 5);
        let o = &pair.optimized[0];
        assert!(
            o.overhead.cpu_share(o.makespan) < 0.05,
            "controller share {}",
            o.overhead.cpu_share(o.makespan)
        );
        let t = run(&ctx);
        assert_eq!(t.n_rows(), 8);
    }
}
