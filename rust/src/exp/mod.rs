//! Experiment harness: one module per paper table/figure plus
//! ablations and the scale-out probe (see DESIGN.md §4 for the index).

pub mod ablation;
pub mod chaos;
pub mod classes;
pub mod common;
pub mod energy;
pub mod faas;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod overhead;
pub mod scale;
pub mod sla;
pub mod utilization;

pub use common::ExpContext;

/// All experiment ids, in presentation order.
pub const ALL: [&str; 12] = [
    "fig1", "fig2", "table1", "table2", "fig3", "fig4", "table3", "table4", "table5",
    "abl1", "abl2", "abl3",
];

/// Run one experiment by id; returns false for unknown ids.
pub fn run(id: &str, ctx: &ExpContext) -> bool {
    let table = match id {
        "fig1" => fig1::run(ctx),
        "fig2" => fig2::run(ctx),
        "table1" => energy::run(ctx),
        "table2" => sla::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => faas::run(ctx),
        "table3" => classes::run(ctx),
        "table4" => utilization::run(ctx),
        "table5" => overhead::run(ctx),
        "abl1" => ablation::run_abl1(ctx),
        "abl2" => ablation::run_abl2(ctx),
        "abl3" => ablation::run_abl3(ctx),
        "scale" => scale::run(ctx),
        "chaos" => chaos::run(ctx),
        "all" => {
            for id in ALL {
                run(id, ctx);
            }
            run("scale", ctx);
            run("chaos", ctx);
            return true;
        }
        _ => return false,
    };
    ctx.write_table(id, &table);
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_rejected() {
        let ctx = ExpContext::fast();
        assert!(!run("bogus", &ctx));
    }
}
