//! Bench: coordinator scale-out — campaign wall time vs cluster size
//! (the §VI-C scale experiment's engine cost).

use ecosched::coordinator::make_policy;
use ecosched::exp::common::run_campaign;
use ecosched::util::bench::{bench_header, Bench};
use ecosched::workload::{Arrivals, Mix, TraceSpec};

fn main() {
    bench_header("scale");
    for n_hosts in [5usize, 20, 80] {
        let n_jobs = 5 * n_hosts;
        let r = Bench::new(&format!("campaign/energy-aware/{n_hosts}-hosts/{n_jobs}-jobs"))
            .warmup(0)
            .samples(3)
            .iters(1)
            .run(|| {
                let trace = TraceSpec {
                    mix: Mix::paper(),
                    n_jobs,
                    arrivals: Arrivals::Poisson {
                        mean_gap: 32.0 * 5.0 / n_hosts as f64,
                    },
                    horizon: 7200.0,
                }
                .generate(1);
                let report =
                    run_campaign(make_policy("energy_aware").unwrap(), trace, 1, n_hosts);
                std::hint::black_box(report.energy_j);
            });
        r.print();
    }
}
