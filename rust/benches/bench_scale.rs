//! Bench: scheduler scale-out. Two parts:
//!
//! 1. `decide_batch` over the sharded cluster state: host counts
//!    {256, 1k, 4k, 10k} × shard counts {1, 4, 16} × worker counts
//!    {1, 4, 8}, measuring burst decision latency and — via a
//!    counting predictor — the feature rows scored per decision. With
//!    top-K routing the per-decision work is bounded by the K largest
//!    shards, so rows/decision must drop well below the fleet size as
//!    shards grow (asserted at 10k hosts: the acceptance gate for the
//!    sharding refactor), and rows/decision must be IDENTICAL across
//!    worker counts (asserted per config: the pool parallelizes, it
//!    never changes the work).
//! 2. (full mode only) end-to-end campaign wall time vs cluster size
//!    — the §VI-C scale experiment's engine cost.
//!
//! Results go to `BENCH_scale.json` (`util::bench::JsonReport`);
//! `BENCH_SHORT` shrinks sample counts but keeps the full sweep so CI
//! records the scaling curve every run. CI's bench gate
//! (`rust/benches/compare.py`) fails the smoke job when rows/decision
//! grows or wall time regresses >25 % against the committed baseline.

use ecosched::cluster::{Cluster, Demand, HostId, ShardedCluster};
use ecosched::coordinator::make_policy;
use ecosched::exp::common::run_campaign;
use ecosched::predict::{oracle_eval, EnergyPredictor, Prediction};
use ecosched::profile::{ResourceVector, FEAT_DIM};
use ecosched::runtime::WorkerPool;
use ecosched::sched::{
    EnergyAware, EnergyAwareParams, PlacementPolicy, PlacementRequest, ScheduleContext,
};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::{Arrivals, JobId, Mix, TraceSpec};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Oracle-equivalent predictor that counts scored rows — the
/// per-decision work measure the sub-linearity gate reads. The
/// counter is shared across `try_clone`d copies so pooled workers
/// account to the same total.
struct CountingOracle {
    rows: Arc<AtomicU64>,
}

impl EnergyPredictor for CountingOracle {
    fn name(&self) -> &'static str {
        "counting-oracle"
    }

    fn predict(&mut self, feats: &[[f32; FEAT_DIM]]) -> Vec<Prediction> {
        self.rows.fetch_add(feats.len() as u64, Ordering::Relaxed);
        feats.iter().map(oracle_eval).collect()
    }

    fn predict_into(&mut self, feats: &[[f32; FEAT_DIM]], out: &mut Vec<Prediction>) {
        self.rows.fetch_add(feats.len() as u64, Ordering::Relaxed);
        out.clear();
        out.extend(feats.iter().map(oracle_eval));
    }

    fn try_clone(&self) -> Option<Box<dyn EnergyPredictor + Send>> {
        Some(Box::new(CountingOracle {
            rows: Arc::clone(&self.rows),
        }))
    }
}

/// Deterministically loaded fleet: mixed demand, everything below
/// δ_high so pruning does not collapse the candidate sets.
fn loaded_cluster(n: usize) -> Cluster {
    let mut c = Cluster::homogeneous(n);
    for i in 0..n {
        c.host_mut(HostId(i)).demand = Demand {
            cpu: (i as f64 * 3.0) % 24.0,
            mem_gb: (i as f64 * 7.0) % 48.0,
            disk_mbps: (i as f64 * 40.0) % 400.0,
            net_mbps: (i as f64 * 11.0) % 100.0,
        };
    }
    c
}

/// A submit burst of varied requests.
fn burst(b: usize) -> Vec<PlacementRequest> {
    (0..b)
        .map(|i| PlacementRequest {
            job: JobId(i as u64),
            flavor: ecosched::cluster::flavor::MEDIUM,
            vector: ResourceVector {
                cpu: 0.2 + 0.6 * (i % 7) as f64 / 7.0,
                mem: 0.5,
                disk: 0.2 + 0.5 * (i % 5) as f64 / 5.0,
                net: 0.3,
                cpu_peak: 0.8,
                io_peak: 0.5,
                burstiness: 0.3,
            },
            remaining_solo: 300.0 + 60.0 * i as f64,
            avoid_rack: None,
        })
        .collect()
}

fn main() {
    bench_header("scale");
    let mut report = JsonReport::new("scale");
    let short = short_mode();
    let samples = if short { 3 } else { 10 };
    const BURST: usize = 64;
    let reqs = burst(BURST);
    let top_k = EnergyAwareParams::default().top_k_shards;

    // rows/decision at (10240 hosts, shards=1) and (10240, shards=16)
    // for the sub-linearity gate.
    let mut rows_flat_10k = 0.0f64;
    let mut rows_sharded_10k = 0.0f64;

    for &n_hosts in &[256usize, 1024, 4096, 10240] {
        let base = loaded_cluster(n_hosts);
        for &shards in &[1usize, 4, 16] {
            let sc = ShardedCluster::new(base.clone(), shards);
            let mut rows_at_one_worker = 0.0f64;
            for &workers in &[1usize, 4, 8] {
                // Persistent pool: spawned once per config, reused by
                // every iteration — the production shape.
                let pool = WorkerPool::new(workers);
                let rows = Arc::new(AtomicU64::new(0));
                let mut policy = EnergyAware::new(
                    Box::new(CountingOracle {
                        rows: Arc::clone(&rows),
                    }),
                    EnergyAwareParams::default(),
                );
                let ctx = ScheduleContext::new(0.0, &sc)
                    .with_shards(&sc)
                    .with_pool(&pool);
                let mut iters = 0u64;
                let r = Bench::new(&format!(
                    "decide_batch/{n_hosts}-hosts/{shards}-shards/{workers}-workers/burst={BURST}"
                ))
                .warmup(1)
                .samples(samples)
                .run(|| {
                    std::hint::black_box(policy.decide_batch(&reqs, &ctx));
                    iters += 1;
                });
                // Rows include the warmup iteration; average over all
                // runs.
                let rows_per_decision =
                    rows.load(Ordering::Relaxed) as f64 / ((iters.max(1) as f64) * BURST as f64);
                r.print_throughput("decisions", BURST as f64);
                println!("      rows/decision: {rows_per_decision:.0} (fleet {n_hosts})");
                report.record_with(
                    &r,
                    &[
                        ("hosts", n_hosts as f64),
                        ("shards", shards as f64),
                        ("workers", workers as f64),
                        ("burst", BURST as f64),
                        ("top_k", top_k as f64),
                        ("rows_per_decision", rows_per_decision),
                    ],
                );
                // The pool parallelizes the sweep; it must not change
                // how much work the sweep does.
                if workers == 1 {
                    rows_at_one_worker = rows_per_decision;
                } else {
                    assert!(
                        (rows_per_decision - rows_at_one_worker).abs() < 1e-9,
                        "worker count changed scored rows: {rows_per_decision} \
                         vs {rows_at_one_worker} ({n_hosts} hosts, {shards} shards, \
                         {workers} workers)"
                    );
                }
                if n_hosts == 10240 && shards == 1 && workers == 1 {
                    rows_flat_10k = rows_per_decision;
                }
                if n_hosts == 10240 && shards == 16 && workers == 1 {
                    rows_sharded_10k = rows_per_decision;
                }
            }
        }
    }

    // Acceptance gate: at 10k hosts, top-K routing over 16 shards
    // must bound per-decision work well below the whole-fleet sweep
    // (expected ≈ K/shards = 1/4 of it).
    assert!(
        rows_sharded_10k < 0.5 * rows_flat_10k,
        "sharded fan-out not sub-linear: {rows_sharded_10k:.0} rows/decision \
         vs {rows_flat_10k:.0} unsharded"
    );

    // End-to-end campaign scale (the §VI-C engine cost) — expensive,
    // full mode only.
    if !short {
        for n_hosts in [5usize, 20, 80] {
            let n_jobs = 5 * n_hosts;
            let r = Bench::new(&format!(
                "campaign/energy-aware/{n_hosts}-hosts/{n_jobs}-jobs"
            ))
            .warmup(0)
            .samples(3)
            .iters(1)
            .run(|| {
                let trace = TraceSpec {
                    mix: Mix::paper(),
                    n_jobs,
                    arrivals: Arrivals::Poisson {
                        mean_gap: 32.0 * 5.0 / n_hosts as f64,
                    },
                    horizon: 7200.0,
                }
                .generate(1);
                let report =
                    run_campaign(make_policy("energy_aware").unwrap(), trace, 1, n_hosts);
                std::hint::black_box(report.energy_j);
            });
            r.print();
            report.record_with(&r, &[("hosts", n_hosts as f64), ("campaign", 1.0)]);
        }
    }

    report.write().expect("write BENCH_scale.json");
}
