//! Bench: predictor inference (Table 5 / Ablation 2 latency column).
//!
//! The headline comparison is per-row scoring (one `predict` call per
//! feature row — what the scheduler's hot path degenerated to before
//! the batched GEMM pipeline) vs `forward_batch`-backed `predict_into`
//! (one call, reusable arena) across batch sizes {1, 8, 64, 128,
//! 1024}. Results are written to `BENCH_predict.json` (see
//! `util::bench::JsonReport`) so the perf trajectory is recorded.

use ecosched::predict::{
    synthesize, DecisionTree, EnergyPredictor, LinearModel, LinearPredictor, MlpWeights,
    NativeMlp, OraclePredictor, Prediction, TreeParams, TreePredictor,
};
use ecosched::profile::FEAT_DIM;
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};

/// Batch sizes the scheduler actually sees: single placements, submit
/// bursts, consolidation scans, and full-fleet sweeps.
const BATCHES: [usize; 5] = [1, 8, 64, 128, 1024];

fn main() {
    bench_header("predict");
    let mut report = JsonReport::new("predict");
    let short = short_mode();
    let samples = if short { 5 } else { 20 };
    let ds = synthesize(2000, 7, None);

    // Per-row vs batched GEMM scoring of the native MLP.
    let mut mlp = NativeMlp::new(MlpWeights::init(42));
    let mut out: Vec<Prediction> = Vec::new();
    for &batch in &BATCHES {
        let feats: Vec<[f32; FEAT_DIM]> =
            (0..batch).map(|i| ds.xs[i % ds.xs.len()]).collect();

        let r = Bench::new(&format!("native-mlp/per-row/B{batch}"))
            .samples(samples)
            .run(|| {
                for row in &feats {
                    std::hint::black_box(mlp.predict(std::slice::from_ref(row)));
                }
            });
        r.print_throughput("rows", batch as f64);
        report.record_with(
            &r,
            &[
                ("batch", batch as f64),
                ("rows_per_s", batch as f64 / r.per_iter.mean),
            ],
        );

        let r = Bench::new(&format!("native-mlp/forward_batch/B{batch}"))
            .samples(samples)
            .run(|| {
                mlp.predict_into(&feats, &mut out);
                std::hint::black_box(&out);
            });
        r.print_throughput("rows", batch as f64);
        report.record_with(
            &r,
            &[
                ("batch", batch as f64),
                ("rows_per_s", batch as f64 / r.per_iter.mean),
            ],
        );
    }

    // Cross-model comparison at the historical batch of 256.
    let feats: Vec<[f32; FEAT_DIM]> = ds.xs[..256].to_vec();

    let mut oracle = OraclePredictor;
    let r = Bench::new("oracle/batch-256").samples(samples).run(|| {
        oracle.predict_into(&feats, &mut out);
        std::hint::black_box(&out);
    });
    r.print_throughput("scores", 256.0);
    report.record_with(&r, &[("batch", 256.0)]);

    let r = Bench::new("native-mlp/batch-256").samples(samples).run(|| {
        mlp.predict_into(&feats, &mut out);
        std::hint::black_box(&out);
    });
    r.print_throughput("scores", 256.0);
    report.record_with(&r, &[("batch", 256.0)]);

    let tree = DecisionTree::fit(&ds.xs, &ds.ys, TreeParams::default());
    let mut tp = TreePredictor::new(tree);
    let r = Bench::new("dtree/batch-256").samples(samples).run(|| {
        std::hint::black_box(tp.predict(&feats));
    });
    r.print_throughput("scores", 256.0);
    report.record_with(&r, &[("batch", 256.0)]);

    let mut lp = LinearPredictor::new(LinearModel::fit(&ds.xs, &ds.ys, 1e-4));
    let r = Bench::new("linear/batch-256").samples(samples).run(|| {
        std::hint::black_box(lp.predict(&feats));
    });
    r.print_throughput("scores", 256.0);
    report.record_with(&r, &[("batch", 256.0)]);

    // Model-fit costs (offline path) — skipped in short mode.
    if !short {
        let r = Bench::new("dtree fit/2000").samples(5).iters(1).run(|| {
            std::hint::black_box(DecisionTree::fit(&ds.xs, &ds.ys, TreeParams::default()));
        });
        r.print();
        report.record(&r);
        let r = Bench::new("linear fit/2000").samples(5).iters(1).run(|| {
            std::hint::black_box(LinearModel::fit(&ds.xs, &ds.ys, 1e-4));
        });
        r.print();
        report.record(&r);
    }

    report.write().expect("write BENCH_predict.json");
}
