//! Bench: predictor inference — oracle vs native MLP vs decision tree
//! vs linear (Table 5 / Ablation 2 latency column).

use ecosched::predict::{
    synthesize, DecisionTree, EnergyPredictor, LinearModel, LinearPredictor, MlpWeights,
    NativeMlp, OraclePredictor, TreeParams, TreePredictor,
};
use ecosched::profile::FEAT_DIM;
use ecosched::util::bench::{bench_header, Bench};

fn main() {
    bench_header("predict");
    let ds = synthesize(2000, 7, None);
    let feats: Vec<[f32; FEAT_DIM]> = ds.xs[..256].to_vec();

    let mut oracle = OraclePredictor;
    Bench::new("oracle/batch-256")
        .run(|| {
            std::hint::black_box(oracle.predict(&feats));
        })
        .print_throughput("scores", 256.0);

    let mut mlp = NativeMlp::new(MlpWeights::init(42));
    Bench::new("native-mlp/batch-256")
        .run(|| {
            std::hint::black_box(mlp.predict(&feats));
        })
        .print_throughput("scores", 256.0);

    let tree = DecisionTree::fit(&ds.xs, &ds.ys, TreeParams::default());
    let mut tp = TreePredictor { tree };
    Bench::new("dtree/batch-256")
        .run(|| {
            std::hint::black_box(tp.predict(&feats));
        })
        .print_throughput("scores", 256.0);

    let mut lp = LinearPredictor {
        model: LinearModel::fit(&ds.xs, &ds.ys, 1e-4),
    };
    Bench::new("linear/batch-256")
        .run(|| {
            std::hint::black_box(lp.predict(&feats));
        })
        .print_throughput("scores", 256.0);

    // Model-fit costs (offline path).
    Bench::new("dtree fit/2000")
        .samples(5)
        .iters(1)
        .run(|| {
            std::hint::black_box(DecisionTree::fit(&ds.xs, &ds.ys, TreeParams::default()));
        })
        .print();
    Bench::new("linear fit/2000")
        .samples(5)
        .iters(1)
        .run(|| {
            std::hint::black_box(LinearModel::fit(&ds.xs, &ds.ys, 1e-4));
        })
        .print();
}
