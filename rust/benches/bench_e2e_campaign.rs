//! Bench: end-to-end campaign throughput (simulated-hours per wall
//! second) for both schedulers — the engine behind Tables 1/2/Fig 3.
//! Emits `BENCH_e2e_campaign.json` for CI's bench gate
//! (`benches/compare.py`).

use ecosched::coordinator::make_policy;
use ecosched::exp::common::{run_campaign, standard_trace};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::Mix;

fn main() {
    bench_header("e2e_campaign");
    let mut report = JsonReport::new("e2e_campaign");
    let (n_jobs, samples) = if short_mode() { (10, 3) } else { (24, 8) };
    for policy in ["round_robin", "best_fit", "energy_aware"] {
        let r = Bench::new(&format!("campaign/{policy}/5-hosts"))
            .warmup(1)
            .samples(samples)
            .iters(1)
            .run(|| {
                let trace = standard_trace(Mix::paper(), n_jobs, 1);
                let report = run_campaign(make_policy(policy).unwrap(), trace, 1, 5);
                std::hint::black_box(report.energy_j);
            });
        r.print();
        report.record_with(&r, &[("jobs", n_jobs as f64), ("hosts", 5.0)]);
    }
    // Simulated-time speedup factor for the default campaign.
    let trace = standard_trace(Mix::paper(), n_jobs, 1);
    let t0 = std::time::Instant::now();
    let run = run_campaign(make_policy("energy_aware").unwrap(), trace, 1, 5);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sim speedup: {:.0}× realtime ({} simulated in {:.2} s wall)",
        run.makespan / wall,
        ecosched::util::table::fmt_dur(run.makespan),
        wall
    );
    report.write().expect("write BENCH_e2e_campaign.json");
}
