//! Bench: end-to-end campaign throughput (simulated-hours per wall
//! second) for both schedulers — the engine behind Tables 1/2/Fig 3.

use ecosched::coordinator::make_policy;
use ecosched::exp::common::{run_campaign, standard_trace};
use ecosched::util::bench::{bench_header, Bench};
use ecosched::workload::Mix;

fn main() {
    bench_header("e2e_campaign");
    for policy in ["round_robin", "best_fit", "energy_aware"] {
        let r = Bench::new(&format!("campaign/{policy}/24-jobs/5-hosts"))
            .warmup(1)
            .samples(8)
            .iters(1)
            .run(|| {
                let trace = standard_trace(Mix::paper(), 24, 1);
                let report = run_campaign(make_policy(policy).unwrap(), trace, 1, 5);
                std::hint::black_box(report.energy_j);
            });
        r.print();
    }
    // Simulated-time speedup factor for the default campaign.
    let trace = standard_trace(Mix::paper(), 24, 1);
    let t0 = std::time::Instant::now();
    let report = run_campaign(make_policy("energy_aware").unwrap(), trace, 1, 5);
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "sim speedup: {:.0}× realtime ({} simulated in {:.2} s wall)",
        report.makespan / wall,
        ecosched::util::table::fmt_dur(report.makespan),
        wall
    );
}
