//! Bench: simulation substrate — event queue, power model, energy
//! meter, telemetry (Fig. 1's engine and everything above it) — plus
//! the campaign-core comparison: the same trace driven by the tick
//! oracle and by the event engine, at sparse and dense utilization.
//! Emits `BENCH_sim_engine.json` for CI's bench gate
//! (`benches/compare.py`); the campaign entries carry
//! `events_processed` and `simulated_s_per_wall_s` tags so the
//! engine-efficiency claim is recorded run over run, and the sparse
//! case *asserts* it: strictly fewer events than tick, and ≥5×
//! simulated-seconds-per-wall-second on the 10k-host fleet.

use ecosched::cluster::{Cluster, Demand, HostId};
use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator, EngineKind};
use ecosched::sim::{EnergyMeter, EventQueue, Telemetry};
use ecosched::util::bench::{bench_header, short_mode, Bench, BenchResult, JsonReport};
use ecosched::workload::{Arrivals, Mix, TraceSpec};
use std::collections::BTreeMap;

/// One campaign-core measurement: run the trace under `engine`,
/// record wall time plus the report-side efficiency tags.
fn campaign_case(
    report: &mut JsonReport,
    name: &str,
    engine: EngineKind,
    n_hosts: usize,
    trace: &[ecosched::workload::Job],
    samples: usize,
) -> (BenchResult, f64, u64) {
    let mut last: Option<ecosched::coordinator::CampaignReport> = None;
    let r = Bench::new(name).warmup(1).samples(samples).iters(1).run(|| {
        let mut coord = Coordinator::new(
            CampaignConfig {
                engine,
                n_hosts,
                worker_threads: 1,
                seed: 11,
                ..Default::default()
            },
            make_policy("round_robin").unwrap(),
        );
        last = Some(coord.run(trace.to_vec()));
    });
    r.print();
    let rep = last.expect("campaign ran");
    let sim_per_wall = rep.makespan / r.per_iter.mean.max(1e-12);
    report.record_with(
        &r,
        &[
            ("hosts", n_hosts as f64),
            ("jobs", trace.len() as f64),
            ("makespan_s", rep.makespan),
            ("events_processed", rep.events_processed as f64),
            ("simulated_s_per_wall_s", sim_per_wall),
        ],
    );
    (r, sim_per_wall, rep.events_processed)
}

fn main() {
    bench_header("sim_engine");
    let mut report = JsonReport::new("sim_engine");
    let samples = if short_mode() { 6 } else { 20 };

    let r = Bench::new("event-queue push+pop (1k events)")
        .samples(samples)
        .run(|| {
            let mut q = EventQueue::new();
            for i in 0..1000u32 {
                q.push((i % 97) as f64, i);
            }
            while let Some(e) = q.pop() {
                std::hint::black_box(e);
            }
        });
    r.print();
    report.record_with(&r, &[("events", 1000.0)]);

    let mut cluster = Cluster::homogeneous(5);
    for i in 0..5 {
        cluster.host_mut(HostId(i)).demand = Demand {
            cpu: 10.0,
            mem_gb: 20.0,
            disk_mbps: 100.0,
            net_mbps: 30.0,
        };
    }
    let r = Bench::new("cluster total_power (5 hosts)")
        .samples(samples)
        .run(|| {
            std::hint::black_box(cluster.total_power());
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    let mut meter = EnergyMeter::new(5, 1, 0.01);
    let mut t = 0.0;
    let r = Bench::new("energy meter sample (5 hosts, noisy)")
        .samples(samples)
        .run(|| {
            t += 1.0;
            meter.sample(t, &cluster);
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    let mut telemetry = Telemetry::new(5, 1, 0.02);
    let demands: BTreeMap<_, _> = cluster
        .vms
        .keys()
        .map(|&vm| (vm, Demand::ZERO))
        .collect();
    let mut ts = 0.0;
    let r = Bench::new("telemetry sample (5 hosts)")
        .samples(samples)
        .run(|| {
            ts += 5.0;
            telemetry.sample(ts, &cluster, &demands);
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    // One full simulated tick equivalent (power states + demands +
    // meter): the per-second cost of the coordinator loop.
    let mut meter2 = EnergyMeter::new(5, 2, 0.01);
    let mut tk = 0.0;
    let r = Bench::new("full tick equivalent (5 hosts)")
        .samples(samples)
        .run(|| {
            tk += 1.0;
            cluster.advance_power_states(tk);
            let d = BTreeMap::new();
            cluster.apply_demands(&d);
            meter2.sample(tk, &cluster);
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    // --- Campaign cores: tick oracle vs event engine -------------------
    //
    // Sparse: a 10k-host fleet where only a handful of hosts ever hold
    // a VM — the regime the event core exists for. The tick engine
    // pays O(hosts) several times per simulated second regardless of
    // occupancy; the event core pays only at the moments something
    // changes (plus one O(hosts) telemetry pass per 5 s).
    let campaign_samples = if short_mode() { 2 } else { 4 };
    let sparse_jobs = if short_mode() { 48 } else { 160 };
    let sparse_trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: sparse_jobs,
        arrivals: Arrivals::Poisson { mean_gap: 12.0 },
        horizon: 1e9,
    }
    .generate(11);
    let (tick_r, tick_spw, tick_events) = campaign_case(
        &mut report,
        "campaign sparse (10k hosts, tick core)",
        EngineKind::Tick,
        10_000,
        &sparse_trace,
        campaign_samples,
    );
    let (event_r, event_spw, event_events) = campaign_case(
        &mut report,
        "campaign sparse (10k hosts, event core)",
        EngineKind::Event,
        10_000,
        &sparse_trace,
        campaign_samples,
    );
    println!(
        "  sparse: events {} -> {} ({:.1}x fewer), sim-s/wall-s {:.0} -> {:.0} ({:.1}x), wall {:.3}s -> {:.3}s",
        tick_events,
        event_events,
        tick_events as f64 / event_events as f64,
        tick_spw,
        event_spw,
        event_spw / tick_spw,
        tick_r.per_iter.mean,
        event_r.per_iter.mean,
    );
    // The acceptance gate for the event core, checked where it is
    // measured: fewer events and ≥5× throughput at sparse occupancy.
    assert!(
        event_events < tick_events,
        "event core must pop strictly fewer events than tick at sparse \
         utilization (event {event_events} >= tick {tick_events})"
    );
    assert!(
        event_spw >= 5.0 * tick_spw,
        "event core must simulate >=5x more seconds per wall second than \
         tick on the sparse 10k-host fleet (event {event_spw:.0}, tick {tick_spw:.0})"
    );

    // Dense: a small fleet near saturation — every host busy, so lazy
    // sync can't skip much and the comparison shows what the event
    // core costs when its advantage is smallest. Recorded, not gated.
    let dense_hosts = 64;
    let dense_jobs = if short_mode() { 96 } else { 256 };
    let dense_trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs: dense_jobs,
        arrivals: Arrivals::Poisson { mean_gap: 1.0 },
        horizon: 1e9,
    }
    .generate(13);
    let (_, tick_dense_spw, _) = campaign_case(
        &mut report,
        "campaign dense (64 hosts, tick core)",
        EngineKind::Tick,
        dense_hosts,
        &dense_trace,
        campaign_samples,
    );
    let (_, event_dense_spw, _) = campaign_case(
        &mut report,
        "campaign dense (64 hosts, event core)",
        EngineKind::Event,
        dense_hosts,
        &dense_trace,
        campaign_samples,
    );
    println!(
        "  dense: sim-s/wall-s {tick_dense_spw:.0} (tick) vs {event_dense_spw:.0} (event)"
    );

    report.write().expect("write BENCH_sim_engine.json");
}
