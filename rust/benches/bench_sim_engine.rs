//! Bench: simulation substrate — event queue, power model, energy
//! meter, telemetry (Fig. 1's engine and everything above it). Emits
//! `BENCH_sim_engine.json` for CI's bench gate (`benches/compare.py`).

use ecosched::cluster::{Cluster, Demand, HostId};
use ecosched::sim::{EnergyMeter, EventQueue, Telemetry};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use std::collections::BTreeMap;

fn main() {
    bench_header("sim_engine");
    let mut report = JsonReport::new("sim_engine");
    let samples = if short_mode() { 6 } else { 20 };

    let r = Bench::new("event-queue push+pop (1k events)")
        .samples(samples)
        .run(|| {
            let mut q = EventQueue::new();
            for i in 0..1000u32 {
                q.push((i % 97) as f64, i);
            }
            while let Some(e) = q.pop() {
                std::hint::black_box(e);
            }
        });
    r.print();
    report.record_with(&r, &[("events", 1000.0)]);

    let mut cluster = Cluster::homogeneous(5);
    for i in 0..5 {
        cluster.host_mut(HostId(i)).demand = Demand {
            cpu: 10.0,
            mem_gb: 20.0,
            disk_mbps: 100.0,
            net_mbps: 30.0,
        };
    }
    let r = Bench::new("cluster total_power (5 hosts)")
        .samples(samples)
        .run(|| {
            std::hint::black_box(cluster.total_power());
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    let mut meter = EnergyMeter::new(5, 1, 0.01);
    let mut t = 0.0;
    let r = Bench::new("energy meter sample (5 hosts, noisy)")
        .samples(samples)
        .run(|| {
            t += 1.0;
            meter.sample(t, &cluster);
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    let mut telemetry = Telemetry::new(5, 1, 0.02);
    let demands: BTreeMap<_, _> = cluster
        .vms
        .keys()
        .map(|&vm| (vm, Demand::ZERO))
        .collect();
    let mut ts = 0.0;
    let r = Bench::new("telemetry sample (5 hosts)")
        .samples(samples)
        .run(|| {
            ts += 5.0;
            telemetry.sample(ts, &cluster, &demands);
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    // One full simulated tick equivalent (power states + demands +
    // meter): the per-second cost of the coordinator loop.
    let mut meter2 = EnergyMeter::new(5, 2, 0.01);
    let mut tk = 0.0;
    let r = Bench::new("full tick equivalent (5 hosts)")
        .samples(samples)
        .run(|| {
            tk += 1.0;
            cluster.advance_power_states(tk);
            let d = BTreeMap::new();
            cluster.apply_demands(&d);
            meter2.sample(tk, &cluster);
        });
    r.print();
    report.record_with(&r, &[("hosts", 5.0)]);

    report.write().expect("write BENCH_sim_engine.json");
}
