//! Bench: the placement decision path (profile → features → predict →
//! argmin) — the latency §V-E's overhead claim rests on — plus the
//! batched API: `decide_batch` (one predictor call per burst) against
//! the per-job sequential loop at batch sizes {1, 8, 64}.
//! Paper artifact: Fig. 2 stages / Table 5 decision latency.
//! Results are written to `BENCH_placement_path.json`; `BENCH_SHORT`
//! shrinks sample counts and cluster sizes for the CI smoke job.

use ecosched::cluster::{Cluster, Demand, HostId};
use ecosched::predict::{EnergyPredictor, MlpWeights, NativeMlp, OraclePredictor};
use ecosched::profile::{build_features, ResourceVector};
use ecosched::sched::{
    Decision, EnergyAware, EnergyAwareParams, PlacementPolicy, PlacementRequest, ScheduleContext,
};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::JobId;

fn loaded_cluster(n: usize) -> Cluster {
    let mut c = Cluster::homogeneous(n);
    for i in 0..n {
        c.host_mut(HostId(i)).demand = Demand {
            cpu: (i as f64 * 3.0) % 24.0,
            mem_gb: (i as f64 * 7.0) % 48.0,
            disk_mbps: (i as f64 * 40.0) % 400.0,
            net_mbps: (i as f64 * 11.0) % 100.0,
        };
    }
    c
}

fn request() -> PlacementRequest {
    PlacementRequest {
        job: JobId(0),
        flavor: ecosched::cluster::flavor::MEDIUM,
        vector: ResourceVector {
            cpu: 0.6,
            mem: 0.5,
            disk: 0.4,
            net: 0.3,
            cpu_peak: 0.8,
            io_peak: 0.5,
            burstiness: 0.3,
        },
        remaining_solo: 600.0,
        avoid_rack: None,
    }
}

/// A burst of distinct requests (varied workload vectors so candidate
/// filtering doesn't collapse to one shape).
fn burst(b: usize) -> Vec<PlacementRequest> {
    (0..b)
        .map(|i| {
            let mut r = request();
            r.job = JobId(i as u64);
            r.vector.cpu = 0.2 + 0.6 * (i % 7) as f64 / 7.0;
            r.vector.disk = 0.2 + 0.5 * (i % 5) as f64 / 5.0;
            r.remaining_solo = 300.0 + 60.0 * i as f64;
            r
        })
        .collect()
}

fn main() {
    bench_header("placement_path");
    let mut report = JsonReport::new("placement_path");
    let short = short_mode();
    let samples = if short { 5 } else { 20 };
    let sizes: &[usize] = if short { &[5, 20] } else { &[5, 20, 80] };
    let req = request();

    // Feature construction alone.
    let cluster = loaded_cluster(5);
    let host = cluster.host(HostId(0));
    let r = Bench::new("build_features(1 host)").samples(samples).run(|| {
        std::hint::black_box(build_features(&req.vector, req.remaining_solo, host));
    });
    r.print();
    report.record(&r);

    // Full decision, oracle predictor (pure-rust floor).
    for &n in sizes {
        let cluster = loaded_cluster(n);
        let ctx = ScheduleContext::new(0.0, &cluster);
        let mut policy = EnergyAware::new(Box::new(OraclePredictor), EnergyAwareParams::default());
        let r = Bench::new(&format!("decide/oracle/{n}-hosts"))
            .samples(samples)
            .run(|| {
                std::hint::black_box(policy.decide(&req, &ctx));
            });
        r.print();
        report.record_with(&r, &[("hosts", n as f64)]);
    }

    // Full decision, native MLP.
    for &n in sizes {
        let cluster = loaded_cluster(n);
        let ctx = ScheduleContext::new(0.0, &cluster);
        let mut policy = EnergyAware::new(
            Box::new(NativeMlp::new(MlpWeights::init(42))),
            EnergyAwareParams::default(),
        );
        let r = Bench::new(&format!("decide/native-mlp/{n}-hosts"))
            .samples(samples)
            .run(|| {
                std::hint::black_box(policy.decide(&req, &ctx));
            });
        r.print();
        report.record_with(&r, &[("hosts", n as f64)]);
    }

    // Batched API: decide_batch (one predictor invocation for the
    // whole burst) vs the sequential per-job loop, 20-host cluster.
    for b in [1usize, 8, 64] {
        let cluster = loaded_cluster(20);
        let ctx = ScheduleContext::new(0.0, &cluster);
        let reqs = burst(b);
        let mut batched = EnergyAware::new(
            Box::new(NativeMlp::new(MlpWeights::init(42))),
            EnergyAwareParams::default(),
        );
        let r = Bench::new(&format!("decide_batch/native-mlp/batch={b}"))
            .samples(samples)
            .run(|| {
                std::hint::black_box(batched.decide_batch(&reqs, &ctx));
            });
        r.print_throughput("decisions", b as f64);
        report.record_with(&r, &[("batch", b as f64), ("batched", 1.0)]);
        let mut sequential = EnergyAware::new(
            Box::new(NativeMlp::new(MlpWeights::init(42))),
            EnergyAwareParams::default(),
        );
        let r = Bench::new(&format!("decide_seq/native-mlp/batch={b}"))
            .samples(samples)
            .run(|| {
                for r in &reqs {
                    std::hint::black_box(sequential.decide(r, &ctx));
                }
            });
        r.print_throughput("decisions", b as f64);
        report.record_with(&r, &[("batch", b as f64), ("batched", 0.0)]);
        // The two paths must agree bit-for-bit.
        assert_eq!(
            batched.decide_batch(&reqs, &ctx),
            reqs.iter().map(|r| sequential.decide(r, &ctx)).collect::<Vec<_>>()
        );
    }

    // Full decision through the XLA/PJRT path (the production Eq. 4).
    let artifacts = ecosched::exp::common::find_artifacts();
    if artifacts.join("meta.json").exists() {
        let weights = MlpWeights::load(&artifacts.join("weights.json"))
            .unwrap_or_else(|| MlpWeights::init(42));
        for n in [5usize, 20, 80] {
            let cluster = loaded_cluster(n);
            let ctx = ScheduleContext::new(0.0, &cluster);
            let runtime = ecosched::runtime::Runtime::new(&artifacts).expect("runtime");
            let xla = ecosched::predict::XlaMlp::new(runtime, weights.clone()).expect("xla");
            let mut policy = EnergyAware::new(Box::new(xla), EnergyAwareParams::default());
            let r = Bench::new(&format!("decide/xla-mlp/{n}-hosts"))
                .samples(12)
                .run(|| {
                    std::hint::black_box(policy.decide(&req, &ctx));
                });
            r.print();
        }
        // Raw batched predict throughput by batch size.
        let runtime = ecosched::runtime::Runtime::new(&artifacts).expect("runtime");
        let mut xla = ecosched::predict::XlaMlp::new(runtime, weights).expect("xla");
        for b in [1usize, 32, 128, 512] {
            let feats = vec![[0.4f32; ecosched::profile::FEAT_DIM]; b];
            Bench::new(&format!("xla predict batch={b}"))
                .samples(12)
                .run(|| {
                    std::hint::black_box(xla.predict(&feats));
                })
                .print_throughput("scores", b as f64);
        }
    } else {
        eprintln!("(artifacts missing — skipping xla benches; run `make artifacts`)");
    }

    // Sanity: decisions must actually place under this load.
    let cluster = loaded_cluster(5);
    let ctx = ScheduleContext::new(0.0, &cluster);
    let mut policy = EnergyAware::new(Box::new(OraclePredictor), EnergyAwareParams::default());
    assert!(matches!(policy.decide(&req, &ctx), Decision::Place(_)));

    report.write().expect("write BENCH_placement_path.json");
}
