#!/usr/bin/env python3
"""Bench regression gate for CI's bench-smoke job.

Compares the freshly generated BENCH_*.json reports (written by the
`cargo bench` targets under BENCH_SHORT=1) against the committed
baselines in rust/benches/baseline/ and fails when:

  * a current report is missing entirely,
  * a baseline config tag (result `name`) is missing from the current
    report,
  * `rows_per_decision` grew for any config tag (scored work is
    deterministic — any growth is a real regression, no tolerance), or
  * mean wall time regressed more than 25 % for any config tag.

Baselines marked `"bootstrap": true` are placeholders committed before
any CI machine ever ran the benches; the gate then only checks that
the current reports exist and are non-empty, and prints a loud warning
asking for a refresh.

Refreshing baselines (run on the reference machine — CI's runner class
— so wall times are comparable):

    cd rust
    BENCH_SHORT=1 cargo bench --bench bench_predict
    BENCH_SHORT=1 cargo bench --bench bench_consolidation
    BENCH_SHORT=1 cargo bench --bench bench_placement_path
    BENCH_SHORT=1 cargo bench --bench bench_scale
    BENCH_SHORT=1 cargo bench --bench bench_pool
    BENCH_SHORT=1 cargo bench --bench bench_e2e_campaign
    BENCH_SHORT=1 cargo bench --bench bench_sim_engine
    BENCH_SHORT=1 cargo bench --bench bench_faas
    BENCH_SHORT=1 cargo bench --bench bench_chaos
    BENCH_SHORT=1 cargo bench --bench bench_commit
    python3 benches/compare.py --update
    git add benches/baseline && git commit

Stdlib only; no third-party imports.
"""

import json
import os
import shutil
import sys

GROUPS = [
    "predict",
    "consolidation",
    "placement_path",
    "scale",
    "pool",
    "e2e_campaign",
    "sim_engine",
    "faas",
    "chaos",
    "commit",
]
WALL_TOLERANCE = 1.25  # fail when mean_s exceeds baseline by >25 %
ROWS_EPS = 1e-6  # float slack on the exact rows/decision comparison


def load(path):
    with open(path) as f:
        return json.load(f)


def results_by_name(doc):
    return {r["name"]: r for r in doc.get("results", [])}


def main():
    update = "--update" in sys.argv
    here = os.path.dirname(os.path.abspath(__file__))
    base_dir = os.path.join(here, "baseline")
    cur_dir = os.environ.get("BENCH_JSON_DIR", ".")
    failures = []
    warnings = []

    for group in GROUPS:
        fname = f"BENCH_{group}.json"
        cur_path = os.path.join(cur_dir, fname)
        if not os.path.exists(cur_path):
            failures.append(f"{group}: missing current report {fname}")
            continue
        cur = load(cur_path)
        if not cur.get("results"):
            failures.append(f"{group}: current report {fname} has no results")
            continue

        if update:
            os.makedirs(base_dir, exist_ok=True)
            shutil.copyfile(cur_path, os.path.join(base_dir, fname))
            print(f"{group}: baseline refreshed from {cur_path}")
            continue

        base_path = os.path.join(base_dir, fname)
        if not os.path.exists(base_path):
            failures.append(f"{group}: missing committed baseline benches/baseline/{fname}")
            continue
        base = load(base_path)
        if base.get("bootstrap"):
            warnings.append(
                f"{group}: baseline is a bootstrap placeholder — wall-time and "
                "rows/decision are NOT being gated; refresh it (see compare.py header)"
            )
            continue
        if base.get("short_mode") != cur.get("short_mode"):
            warnings.append(
                f"{group}: short_mode differs between baseline and current report; "
                "wall-time comparison may be meaningless"
            )

        cur_rows = results_by_name(cur)
        for name, b in results_by_name(base).items():
            c = cur_rows.get(name)
            if c is None:
                failures.append(f"{group}: config '{name}' missing from current report")
                continue
            if "rows_per_decision" in b and "rows_per_decision" in c:
                if c["rows_per_decision"] > b["rows_per_decision"] + ROWS_EPS:
                    failures.append(
                        f"{group}: '{name}' rows/decision grew "
                        f"{b['rows_per_decision']:.1f} -> {c['rows_per_decision']:.1f}"
                    )
            if "mean_s" in b and "mean_s" in c and b["mean_s"] > 0:
                if c["mean_s"] > WALL_TOLERANCE * b["mean_s"]:
                    failures.append(
                        f"{group}: '{name}' wall time regressed "
                        f"{b['mean_s']:.6f}s -> {c['mean_s']:.6f}s "
                        f"(>{(WALL_TOLERANCE - 1) * 100:.0f}%)"
                    )

    for w in warnings:
        print(f"::warning::{w}")
    if failures:
        for f in failures:
            print(f"::error::{f}")
        return 1
    if not update:
        print("bench gate: all reports present and within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
