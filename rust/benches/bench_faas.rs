//! Bench: serverless front-end throughput — invocations per wall
//! second for a full campaign replay of a Burr-sampled
//! Azure-2021-shaped trace through the FaaS path (cold starts, warm
//! pool claims, keep-alive expiry scans) at fleet sizes {1k, 10k}.
//! Emits `BENCH_faas.json` for CI's bench gate (`benches/compare.py`).

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::faas::FaasConfig;
use ecosched::workload::FaasTraceSpec;

fn main() {
    bench_header("faas");
    let mut report = JsonReport::new("faas");
    let (n_invocations, samples) = if short_mode() { (2_000, 3) } else { (20_000, 5) };

    for &n_hosts in &[1_000usize, 10_000] {
        let spec = FaasTraceSpec {
            n_functions: 200,
            n_invocations,
            ..Default::default()
        };
        let trace = spec.generate(1);
        let shard_count = if n_hosts >= 10_000 { 64 } else { 16 };
        let r = Bench::new(&format!("faas/replay/{n_hosts}-hosts"))
            .warmup(1)
            .samples(samples)
            .iters(1)
            .run(|| {
                let mut coord = Coordinator::new(
                    CampaignConfig {
                        n_hosts,
                        shard_count,
                        seed: 1,
                        faas: Some(FaasConfig::default()),
                        ..Default::default()
                    },
                    make_policy("round_robin").unwrap(),
                );
                let rep = coord.run(trace.clone());
                assert_eq!(
                    rep.cold_starts + rep.warm_starts,
                    n_invocations as u64,
                    "every invocation must resolve cold or warm"
                );
                std::hint::black_box(rep.cold_start_rate());
            });
        r.print_throughput("invocations", n_invocations as f64);
        report.record_with(
            &r,
            &[
                ("hosts", n_hosts as f64),
                ("invocations", n_invocations as f64),
                ("inv_per_s", n_invocations as f64 / r.per_iter.mean),
            ],
        );
    }

    report.write().expect("write BENCH_faas.json");
}
