//! Bench: per-fan-out dispatch overhead — the spawn-per-call
//! `ShardPool` (PR 4, rebuilds worker states and respawns scoped
//! threads every call) vs the persistent `WorkerPool` (PR 5,
//! long-lived threads + epoch-cached worker state), plus the inline
//! serial path the small-burst fast path falls back to.
//!
//! The measured job is a fixed 8-shard scoring fan-out shaped like a
//! `decide_batch` sweep: each shard job scores `burst × 8` feature
//! rows through a NativeMlp. The per-job row count is kept small on
//! purpose — this bench isolates *dispatch overhead*, so compute must
//! not drown the spawn/rebuild delta even at the largest burst
//! (`bench_scale` covers compute-bound scaling). Burst sizes
//! {1, 8, 64, 512} × worker counts {1, 4, 8}:
//!
//! * `pool/spawn/...`      — ShardPool::scatter_state, building every
//!   worker's state (predictor clone + arenas) per call: the per-call
//!   overhead PR 5 removes.
//! * `pool/persistent/...` — WorkerPool::dispatch against slot-cached
//!   state (clone + arenas built once, first call only).
//! * `pool/inline/...`     — the serial sweep, one predictor, no
//!   dispatch: what `EnergyAwareParams::inline_burst_rows` selects
//!   for small bursts.
//!
//! Acceptance (asserted below): the persistent pool beats
//! spawn-per-call at EVERY burst size for workers > 1, and at burst
//! size 1 the inline path beats dispatch — the measurement the
//! `inline_burst_rows` default is derived from. Results go to
//! `BENCH_pool.json` for CI's bench gate (`benches/compare.py`).

use ecosched::predict::{EnergyPredictor, MlpWeights, NativeMlp, Prediction};
use ecosched::profile::FEAT_DIM;
use ecosched::runtime::{ShardPool, WorkerPool, WorkerSlot};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};

/// Shard jobs per fan-out (a top-K = 8 sweep).
const SHARDS: usize = 8;
/// Feature rows per request per shard job (small: see module docs).
const ROWS_PER_REQ: usize = 8;

/// Deterministic feature rows for one shard job.
fn shard_feats(shard: usize, burst: usize) -> Vec<[f32; FEAT_DIM]> {
    (0..burst * ROWS_PER_REQ)
        .map(|i| {
            let mut f = [0f32; FEAT_DIM];
            for (j, v) in f.iter_mut().enumerate() {
                *v = ((shard * 31 + i * 7 + j) % 97) as f32 / 97.0;
            }
            f
        })
        .collect()
}

/// Per-worker state for the spawn-per-call variant — rebuilt every
/// fan-out, exactly like PR 4's sweep workers.
struct SpawnWorker {
    predictor: Box<dyn EnergyPredictor + Send>,
    preds: Vec<Prediction>,
}

/// Per-worker state the persistent variant caches in its slot.
struct CachedWorker {
    predictor: Box<dyn EnergyPredictor + Send>,
    preds: Vec<Prediction>,
}

fn checksum(preds: &[Prediction]) -> f64 {
    preds.iter().map(|p| p.power_w + p.slowdown).sum()
}

fn main() {
    bench_header("pool");
    let mut report = JsonReport::new("pool");
    // Enough samples for a stable minimum — the acceptance asserts
    // below compare min-of-samples, the robust estimator for a
    // mandatory-overhead comparison (runner noise only ever ADDS
    // time, and both variants run the identical scoring work, so the
    // minima isolate the dispatch/rebuild overhead delta).
    let samples = if short_mode() { 9 } else { 21 };
    let mlp = NativeMlp::new(MlpWeights::init(42));

    for &burst in &[1usize, 8, 64, 512] {
        let feats: Vec<Vec<[f32; FEAT_DIM]>> =
            (0..SHARDS).map(|s| shard_feats(s, burst)).collect();

        // Inline serial reference: one predictor, no dispatch — the
        // small-burst fast path.
        let mut inline_mlp = mlp.clone();
        let mut inline_preds: Vec<Prediction> = Vec::new();
        let r_inline = Bench::new(&format!("pool/inline/burst={burst}"))
            .samples(samples)
            .run(|| {
                let mut acc = 0.0;
                for f in &feats {
                    inline_mlp.predict_into(f, &mut inline_preds);
                    acc += checksum(&inline_preds);
                }
                std::hint::black_box(acc);
            });
        r_inline.print();
        report.record_with(&r_inline, &[("burst", burst as f64), ("workers", 1.0)]);

        for &workers in &[1usize, 4, 8] {
            // Spawn-per-call: per fan-out, build min(workers, jobs)
            // worker states (predictor clone + fresh arena) and run a
            // scoped-thread scatter.
            let spawn_pool = ShardPool::new(workers);
            let r_spawn = Bench::new(&format!("pool/spawn/burst={burst}/workers={workers}"))
                .samples(samples)
                .run(|| {
                    let n = spawn_pool.plan_workers(SHARDS);
                    let states: Vec<SpawnWorker> = (0..n)
                        .map(|_| SpawnWorker {
                            predictor: mlp.try_clone().expect("native mlp clones"),
                            preds: Vec::new(),
                        })
                        .collect();
                    let jobs: Vec<_> = feats
                        .iter()
                        .map(|f| {
                            move |w: &mut SpawnWorker| {
                                w.predictor.predict_into(f, &mut w.preds);
                                checksum(&w.preds)
                            }
                        })
                        .collect();
                    let out = spawn_pool.scatter_state(states, jobs).expect("scatter");
                    std::hint::black_box(out.iter().sum::<f64>());
                });
            r_spawn.print();
            report.record_with(&r_spawn, &[("burst", burst as f64), ("workers", workers as f64)]);

            // Persistent: long-lived threads, slot-cached clone +
            // arena (built on each worker's first-ever job only).
            let persist_pool = WorkerPool::new(workers);
            let r_persist =
                Bench::new(&format!("pool/persistent/burst={burst}/workers={workers}"))
                    .samples(samples)
                    .run(|| {
                        let jobs: Vec<_> = feats
                            .iter()
                            .enumerate()
                            .map(|(s, f)| {
                                let mlp = &mlp;
                                (s, move |slot: &mut WorkerSlot| {
                                    let w = slot.state_or_insert_with(|| CachedWorker {
                                        predictor: mlp
                                            .try_clone()
                                            .expect("native mlp clones"),
                                        preds: Vec::new(),
                                    });
                                    w.predictor.predict_into(f, &mut w.preds);
                                    checksum(&w.preds)
                                })
                            })
                            .collect();
                        let out = persist_pool.dispatch(jobs).expect("dispatch");
                        std::hint::black_box(out.iter().sum::<f64>());
                    });
            r_persist.print();
            report.record_with(
                &r_persist,
                &[("burst", burst as f64), ("workers", workers as f64)],
            );

            // Acceptance: removing the per-call rebuild + spawn must
            // actually pay at every burst size once the pool is
            // parallel. Min-of-samples, not mean/p50 — noise on a
            // shared CI runner is one-sided, and both variants run
            // identical scoring work, so the minima expose the
            // structural overhead difference without flaking.
            if workers > 1 {
                assert!(
                    r_persist.per_iter.min < r_spawn.per_iter.min,
                    "persistent pool slower than spawn-per-call at burst {burst}, \
                     workers {workers}: {:.2e}s vs {:.2e}s",
                    r_persist.per_iter.min,
                    r_spawn.per_iter.min
                );
            }
            // Acceptance: at burst 1 the inline path must beat
            // dispatch — the measurement behind the
            // `inline_burst_rows` small-burst fast path.
            if burst == 1 && workers > 1 {
                assert!(
                    r_inline.per_iter.min < r_persist.per_iter.min,
                    "inline path slower than dispatch at burst 1, workers {workers}: \
                     {:.2e}s vs {:.2e}s",
                    r_inline.per_iter.min,
                    r_persist.per_iter.min
                );
            }
        }
    }

    report.write().expect("write BENCH_pool.json");
}
