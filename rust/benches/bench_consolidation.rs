//! Bench: consolidation scan cost (Tables 3/4, Ablation 1) as cluster
//! size grows — the coordinator must stay off the critical path.
//!
//! Measures the batched scan (ONE predictor call per scan) against
//! the sequential per-donor-VM reference (`scan_sequential`) at each
//! cluster size, and writes `BENCH_consolidation.json`.

use ecosched::cluster::{Cluster, Demand, HostId};
use ecosched::predict::{MlpWeights, NativeMlp};
use ecosched::profile::ResourceVector;
use ecosched::sched::{
    ConsolidationParams, Consolidator, ControlLoop, ScheduleContext, VmContext,
};
use ecosched::sim::Telemetry;
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::JobId;
use std::collections::BTreeMap;

fn setup(n_hosts: usize) -> (Cluster, Telemetry, BTreeMap<ecosched::cluster::VmId, VmContext>) {
    let mut c = Cluster::homogeneous(n_hosts);
    let mut ctxs = BTreeMap::new();
    // 2 VMs per host, light load on even hosts (consolidation donors).
    for h in 0..n_hosts {
        for k in 0..2 {
            let vm = c.create_vm(
                ecosched::cluster::flavor::MEDIUM,
                JobId((h * 2 + k) as u64),
                0.0,
            );
            c.place_vm(vm, HostId(h)).unwrap();
            ctxs.insert(
                vm,
                VmContext {
                    vector: ResourceVector {
                        cpu: 0.2,
                        mem: 0.4,
                        disk: 0.4,
                        net: 0.3,
                        cpu_peak: 0.3,
                        io_peak: 0.5,
                        burstiness: 0.2,
                    },
                    remaining_solo: 500.0,
                    slack_left: 0.08,
                },
            );
        }
        c.host_mut(HostId(h)).demand = if h % 2 == 0 {
            Demand {
                cpu: 1.5,
                mem_gb: 8.0,
                disk_mbps: 60.0,
                net_mbps: 15.0,
            }
        } else {
            Demand {
                cpu: 12.0,
                mem_gb: 20.0,
                disk_mbps: 150.0,
                net_mbps: 40.0,
            }
        };
    }
    let mut t = Telemetry::new(n_hosts, 1, 0.0);
    for k in 1..=25 {
        t.sample(k as f64 * 5.0, &c, &BTreeMap::new());
    }
    (c, t, ctxs)
}

fn main() {
    bench_header("consolidation");
    let mut report = JsonReport::new("consolidation");
    let short = short_mode();
    let samples = if short { 5 } else { 20 };
    let sizes: &[usize] = if short { &[5, 20] } else { &[5, 20, 80] };
    for &n in sizes {
        let (c, t, ctxs) = setup(n);
        // The MLP predictor exercises the real batched-GEMM scoring
        // path (the oracle is closed-form and would hide it).
        let mut pred = NativeMlp::new(MlpWeights::init(42));
        let ctx = ScheduleContext::new(1000.0, &c)
            .with_telemetry(&t)
            .with_vm_ctx(&ctxs);

        let mut cons = Consolidator::new(ConsolidationParams::default());
        let r = Bench::new(&format!("scan-batched/{n}-hosts/{}-vms", 2 * n))
            .samples(samples)
            .run(|| {
                std::hint::black_box(cons.scan(&ctx, Some(&mut pred)));
            });
        r.print();
        report.record_with(&r, &[("hosts", n as f64), ("batched", 1.0)]);

        let mut cons = Consolidator::new(ConsolidationParams::default());
        let r = Bench::new(&format!("scan-sequential/{n}-hosts/{}-vms", 2 * n))
            .samples(samples)
            .run(|| {
                std::hint::black_box(cons.scan_sequential(&ctx, &mut pred));
            });
        r.print();
        report.record_with(&r, &[("hosts", n as f64), ("batched", 0.0)]);
    }
    report.write().expect("write BENCH_consolidation.json");
}
