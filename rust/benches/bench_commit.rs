//! Bench: commit-protocol throughput — what splitting the leader into
//! N coordinators buys. N real threads each decide a round-robin
//! slice of one contended burst against the same frozen
//! `ShardedCluster` (planning is pure, so sharing it immutably is
//! safe), then a single-threaded commit phase validates the merged
//! commits in total order through a `PlacementStore`, re-deciding
//! rejects against the live cluster. Reported per coordinator count:
//! decisions/s (the parallel decide phase) and the conflict rate the
//! optimism costs (rejected / total commits).
//!
//! The fleet is deliberately contended: all but every 16th host is
//! pre-filled to capacity, so every coordinator's scorer chases the
//! same small set of free hosts and double-books across slices.
//!
//! Asserts decisions/s at N = 4 reaches >= 2x N = 1 when the machine
//! actually has >= 4 cores (the campaign driver itself runs decide
//! phases sequentially for determinism; this bench is where the
//! protocol's parallel headroom is measured). Emits
//! `BENCH_commit.json` for CI's bench gate (`benches/compare.py`).

use ecosched::cluster::flavor::{LARGE, MEDIUM};
use ecosched::cluster::{Cluster, Demand, HostId, ShardedCluster};
use ecosched::coordinator::{
    commit_order, target_shard, AllocationCommit, CommitOutcome, CommitRecord, PlacementStore,
    RejectReason, Scheduler,
};
use ecosched::predict::OraclePredictor;
use ecosched::profile::ResourceVector;
use ecosched::sched::{
    Decision, EnergyAware, EnergyAwareParams, PlacementPolicy, PlacementRequest, ScheduleContext,
};
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::JobId;

const N_HOSTS: usize = 10_000;
const SHARDS: usize = 64;

fn fresh_policy() -> EnergyAware {
    EnergyAware::new(Box::new(OraclePredictor), EnergyAwareParams::default())
}

/// 10k hosts with all but every 16th pre-filled by two LARGE VMs
/// (which exactly exhaust a paper-testbed host's memory): 625 hosts
/// of headroom for the scorers to fight over.
fn contended_fleet() -> ShardedCluster {
    let mut sc = ShardedCluster::new(Cluster::homogeneous(N_HOSTS), SHARDS);
    for h in 0..N_HOSTS {
        if h % 16 == 0 {
            continue;
        }
        for k in 0..2 {
            let vm = sc.create_vm(LARGE, JobId((1_000_000 + 2 * h + k) as u64), 0.0);
            sc.place_vm(vm, HostId(h)).expect("prefill fits");
            sc.set_expected_demand(
                vm,
                Demand {
                    cpu: LARGE.vcpus * 0.6,
                    mem_gb: LARGE.mem_gb * 0.7,
                    disk_mbps: LARGE.disk_mbps * 0.2,
                    net_mbps: LARGE.net_mbps * 0.2,
                },
            );
        }
    }
    sc
}

fn requests(n: usize) -> Vec<PlacementRequest> {
    (0..n)
        .map(|i| PlacementRequest {
            job: JobId(i as u64),
            flavor: MEDIUM,
            vector: ResourceVector {
                cpu: 0.55 + 0.01 * (i % 8) as f64,
                mem: 0.7,
                disk: 0.25,
                net: 0.15,
                cpu_peak: 0.85,
                io_peak: 0.35,
                ..Default::default()
            },
            remaining_solo: 600.0 + i as f64,
            avoid_rack: None,
        })
        .collect()
}

/// Parallel decide phase: request i goes to coordinator i mod n, each
/// coordinator is a real thread owning its own policy (predictor
/// state is not `Send`, so it must be built inside the thread), all
/// deciding against the same frozen cluster.
fn decide_parallel(n: usize, reqs: &[PlacementRequest], sc: &ShardedCluster) -> Vec<Decision> {
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|c| {
                scope.spawn(move || {
                    let mut policy = fresh_policy();
                    let ctx = ScheduleContext::new(0.0, sc).with_shards(sc);
                    let idxs: Vec<usize> = (c..reqs.len()).step_by(n).collect();
                    let sub: Vec<PlacementRequest> =
                        idxs.iter().map(|&i| reqs[i].clone()).collect();
                    let decisions = policy.decide_batch(&sub, &ctx);
                    idxs.into_iter().zip(decisions).collect::<Vec<_>>()
                })
            })
            .collect();
        let mut out = vec![Decision::Defer; reqs.len()];
        for h in handles {
            for (i, d) in h.join().expect("coordinator thread panicked") {
                out[i] = d;
            }
        }
        out
    })
}

/// Single-threaded commit phase on a fresh clone of the fleet: sort
/// into total commit order, validate each commit, actuate winners,
/// re-decide losers against the live cluster (the campaign driver's
/// discipline, minus the event machinery). Returns (commits,
/// conflicts).
fn commit_all(
    n: usize,
    reqs: &[PlacementRequest],
    decisions: &[Decision],
    base: &ShardedCluster,
) -> (u64, u64) {
    let mut cluster = base.clone();
    let mut store = PlacementStore::new();
    let mut scheds: Vec<Scheduler> = (0..n as u32).map(|c| Scheduler::new(c, SHARDS)).collect();
    let mut re_policy = fresh_policy();

    let mut commits: Vec<AllocationCommit> = Vec::with_capacity(reqs.len());
    for (c, sched) in scheds.iter_mut().enumerate() {
        sched.refresh_snapshot(&cluster);
        for i in (c..reqs.len()).step_by(n) {
            commits.push(sched.request(0.0, 2, &cluster, reqs[i].job, reqs[i].flavor, decisions[i]));
        }
    }
    commits.sort_by(commit_order);

    let mut placed: Vec<HostId> = Vec::new();
    for mut commit in commits {
        let coord = commit.coordinator as usize;
        // Own writes are always visible (same rule as the campaign
        // driver): raise the stamp to the committer's current view.
        if let (Some(shard), Some(snap)) = (
            target_shard(&cluster, commit.decision),
            commit.snapshot_epoch.as_mut(),
        ) {
            *snap = (*snap).max(scheds[coord].snapshot_epoch(shard));
        }
        let req = &reqs[commit.job.0 as usize];
        let verdict = store.validate(&cluster, &commit, &placed, true, 64);
        let (outcome, decision) = match verdict {
            Ok(()) => (CommitOutcome::Committed, commit.decision),
            Err(reason) => {
                if matches!(reason, RejectReason::StaleSnapshot { .. }) {
                    scheds[coord].refresh_snapshot(&cluster);
                }
                let redecided = {
                    let ctx = ScheduleContext::new(0.0, &cluster).with_shards(&cluster);
                    re_policy.decide(req, &ctx)
                };
                (CommitOutcome::Rejected(reason), redecided)
            }
        };
        if let Decision::Place(host) = decision {
            let vm = cluster.create_vm(req.flavor, req.job, 0.0);
            cluster
                .place_vm(vm, host)
                .expect("validated placement must fit");
            cluster.set_expected_demand(
                vm,
                Demand {
                    cpu: req.vector.cpu * req.flavor.vcpus,
                    mem_gb: req.vector.mem * req.flavor.mem_gb,
                    disk_mbps: req.vector.disk * req.flavor.disk_mbps,
                    net_mbps: req.vector.net * req.flavor.net_mbps,
                },
            );
            if !placed.contains(&host) {
                placed.push(host);
            }
        }
        if let Some(shard) = target_shard(&cluster, decision) {
            let epoch = cluster.shard_epoch(shard);
            scheds[coord].note_commit(shard, epoch);
        }
        store.record(CommitRecord {
            time: commit.time,
            class: commit.class,
            coordinator: commit.coordinator,
            seq: commit.seq,
            job: commit.job,
            requested: commit.decision,
            outcome,
            decision,
        });
    }
    (store.commits(), store.conflicts())
}

fn main() {
    bench_header("commit");
    let mut report = JsonReport::new("commit");
    let (n_reqs, samples) = if short_mode() { (512, 3) } else { (2048, 5) };

    let fleet = contended_fleet();
    let reqs = requests(n_reqs);
    let mut decisions_per_s = Vec::new();

    for &n in &[1usize, 2, 4] {
        let r = Bench::new(&format!("commit/decide/n{n}"))
            .warmup(1)
            .samples(samples)
            .iters(1)
            .run(|| {
                let ds = decide_parallel(n, &reqs, &fleet);
                std::hint::black_box(ds.len());
            });
        let dps = n_reqs as f64 / r.per_iter.mean;
        decisions_per_s.push(dps);

        let ds = decide_parallel(n, &reqs, &fleet);
        let placed = ds
            .iter()
            .filter(|d| matches!(d, Decision::Place(_)))
            .count();
        assert!(
            placed > 0,
            "n={n}: the contended fleet must still admit placements"
        );
        let (commits, conflicts) = commit_all(n, &reqs, &ds, &fleet);
        assert_eq!(commits as usize, n_reqs, "one commit per request");
        if n > 1 {
            assert!(
                conflicts > 0,
                "n={n}: contended slices must double-book at least once"
            );
        }
        report.record_with(
            &r,
            &[
                ("coordinators", n as f64),
                ("requests", n_reqs as f64),
                ("decisions_per_s", dps),
                ("commits", commits as f64),
                ("conflicts", conflicts as f64),
                ("conflict_rate", conflicts as f64 / commits as f64),
            ],
        );
        println!(
            "bench commit/decide/n{n}: {dps:.0} decisions/s, conflict rate {:.3}",
            conflicts as f64 / commits as f64
        );
    }

    // The protocol's parallel headroom: 4 coordinators must at least
    // double single-coordinator decision throughput — on hardware
    // that can actually run them concurrently.
    let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
    if cores >= 4 {
        assert!(
            decisions_per_s[2] >= 2.0 * decisions_per_s[0],
            "n=4 decided {:.0}/s, n=1 decided {:.0}/s — expected >= 2x",
            decisions_per_s[2],
            decisions_per_s[0]
        );
    } else {
        println!("::warning::commit bench on {cores} cores — skipping the 2x speedup assert");
    }

    report.write().expect("write BENCH_commit.json");
}
