//! Bench: fault-injection overhead — wall time of a faulted campaign
//! (host crashes, evacuations, blackouts, migration-failure oracle)
//! vs the identical fault-free campaign, at worker widths 1 and 4.
//! The `rack_ckpt` scenario layers correlated rack crashes, partial
//! degradation, and checkpoint/restart on top, so the bench covers
//! the full fault pipeline. Fault intensities come from the chaos
//! experiment's [`ChaosGrid`] — one source of truth for both.
//! Asserts the faulted runs actually crash hosts and stay
//! deterministic (fingerprint-equal across samples). Emits
//! `BENCH_chaos.json` for CI's bench gate (`benches/compare.py`).

use ecosched::coordinator::{make_policy, CampaignConfig, Coordinator};
use ecosched::exp::chaos::ChaosGrid;
use ecosched::util::bench::{bench_header, short_mode, Bench, JsonReport};
use ecosched::workload::{Arrivals, Mix, TraceSpec};

fn main() {
    bench_header("chaos");
    let mut report = JsonReport::new("chaos");
    let (n_jobs, samples) = if short_mode() { (16, 3) } else { (48, 5) };

    let trace = TraceSpec {
        mix: Mix::paper(),
        n_jobs,
        arrivals: Arrivals::Poisson { mean_gap: 40.0 },
        horizon: 7200.0,
    }
    .generate(7);

    let grid = ChaosGrid::fast();
    for &(tag, faults) in &[
        ("clean", None),
        ("faulted", Some(grid.fault_config(2.0, false, None))),
        // Correlated fault domains + degradation + checkpointing:
        // rack crashes fan out over the 4 shard-derived racks.
        ("rack_ckpt", Some(grid.fault_config(2.0, true, Some(60.0)))),
    ] {
        for &workers in &[1usize, 4] {
            let mut fingerprints = Vec::new();
            let r = Bench::new(&format!("chaos/campaign/{tag}/w{workers}"))
                .warmup(1)
                .samples(samples)
                .iters(1)
                .run(|| {
                    let mut coord = Coordinator::new(
                        CampaignConfig {
                            n_hosts: 8,
                            shard_count: 4,
                            seed: 7,
                            worker_threads: workers,
                            faults,
                            ..Default::default()
                        },
                        make_policy("round_robin").unwrap(),
                    );
                    let rep = coord.run(trace.clone());
                    if faults.is_some() {
                        assert!(rep.host_crashes > 0, "fault plan never crashed a host");
                    }
                    if tag == "rack_ckpt" {
                        assert!(rep.rack_crashes > 0, "rack scenario never crashed a rack");
                        assert!(rep.checkpoints_taken > 0, "no checkpoints were written");
                    }
                    assert_eq!(
                        rep.jobs.len() + rep.interrupted_jobs,
                        n_jobs,
                        "every job must finish or be interrupted"
                    );
                    fingerprints.push(rep.fingerprint());
                    std::hint::black_box(rep.energy_j);
                });
            assert!(
                fingerprints.windows(2).all(|w| w[0] == w[1]),
                "faulted campaign not deterministic across samples"
            );
            report.record_with(
                &r,
                &[
                    ("jobs", n_jobs as f64),
                    ("workers", workers as f64),
                    ("jobs_per_s", n_jobs as f64 / r.per_iter.mean),
                ],
            );
        }
    }

    report.write().expect("write BENCH_chaos.json");
}
