//! API-compatible stub of the `xla-rs` PJRT bindings.
//!
//! The container image carries no libxla / PJRT shared objects, so
//! this crate keeps the call sites in `ecosched::runtime` compiling
//! while reporting the backend as unavailable at runtime:
//! [`PjRtClient::cpu`] (the first call on every code path) returns
//! [`Error::Unavailable`], and `ecosched` falls back to the native
//! MLP predictor when trained weights exist on disk, else to the
//! analytic oracle. Replace this path dependency with the real
//! `xla-rs` to enable HLO execution — no ecosched source changes.

use std::fmt;
use std::path::Path;

/// XLA errors. The stub only ever produces `Unavailable`.
#[derive(Debug, Clone)]
pub enum Error {
    Unavailable(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "XLA backend unavailable ({what}): built against the stub xla crate"
            ),
        }
    }
}

impl std::error::Error for Error {}

fn unavailable<T>(what: &str) -> Result<T, Error> {
    Err(Error::Unavailable(what.to_string()))
}

/// Host-side literal tensor.
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        unavailable("Literal::to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable("Literal::to_vec")
    }
}

/// Device-resident buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &Path) -> Result<HloModuleProto, Error> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation ready to compile.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

/// The PJRT client. Construction fails in the stub, so nothing
/// downstream of it can be reached.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer, Error> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }

    #[test]
    fn literal_ops_report_unavailable() {
        assert!(Literal::vec1(&[1.0]).reshape(&[1, 1]).is_err());
        assert!(HloModuleProto::from_text_file(Path::new("/nope")).is_err());
    }
}
