//! Minimal, API-compatible stand-in for the `log` facade crate.
//!
//! The offline vendor set has no crates.io access, so this implements
//! the subset `ecosched` uses: the five level macros, `Level` /
//! `LevelFilter`, the `Log` trait, `set_boxed_logger`, and
//! `set_max_level`. Swap this path dependency for the real `log`
//! crate when a registry is available — no call sites change.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Logging levels, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    pub fn as_str(&self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Maximum-level filter (`Off` disables everything).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a log record.
#[derive(Debug, Clone, Copy)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record as handed to the installed logger.
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
    module_path: Option<&'a str>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }

    pub fn module_path(&self) -> Option<&'a str> {
        self.module_path
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }
}

/// Logger sink interface.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);
static LOGGER: OnceLock<Box<dyn Log>> = OnceLock::new();

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install a boxed logger; fails if one is already set.
pub fn set_boxed_logger(logger: Box<dyn Log>) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// Current global maximum level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — not public API.
#[doc(hidden)]
pub fn __private_log(level: Level, module_path: &'static str, args: fmt::Arguments) {
    if level as usize > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record {
            metadata: Metadata {
                level,
                target: module_path,
            },
            args,
            module_path: Some(module_path),
        };
        logger.log(&record);
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Error, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => {
        $crate::__private_log($crate::Level::Trace, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Trace);
        assert!((Level::Warn as usize) <= (LevelFilter::Warn as usize));
    }

    #[test]
    fn filter_gates_dispatch() {
        // No logger installed: must not panic either way.
        set_max_level(LevelFilter::Info);
        info!("visible {}", 1);
        debug!("filtered {}", 2);
        assert_eq!(max_level(), LevelFilter::Info);
    }
}
